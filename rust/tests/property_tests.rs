//! Property-based tests (in-tree driver: deterministic SplitMix64 sweeps
//! over randomized parameters — the offline substitute for proptest).
//!
//! Each property runs against dozens of randomly drawn configurations;
//! failures print the exact parameters for reproduction.

use camr::agg::{lanes, Aggregator, MaxU64, SumF32, SumU64, XorBytes};
use camr::analysis::{jobs, load};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::design::{verify::verify_design, ResolvableDesign};
use camr::placement::{storage::audit_storage, Placement};
use camr::shuffle::buf::{self, BufferPool};
use camr::shuffle::multicast::GroupPlan;
use camr::shuffle::plan::ChunkSpec;
use camr::shuffle::packet;
use camr::util::rng::SplitMix64;
use camr::workload::synth::SyntheticWorkload;

/// Draw a random-but-small (k, q) pair.
fn draw_kq(rng: &mut SplitMix64) -> (usize, usize) {
    let k = rng.range(2, 6);
    // Cap q so q^{k-1} stays small enough for dozens of runs.
    let qmax = match k {
        2 => 13,
        3 => 7,
        4 => 4,
        _ => 3,
    };
    (k, rng.range(2, qmax))
}

#[test]
fn prop_design_invariants_hold() {
    let mut rng = SplitMix64::new(0xD0_0D);
    for case in 0..60 {
        let (k, q) = draw_kq(&mut rng);
        let d = ResolvableDesign::new(k, q).unwrap();
        verify_design(&d).unwrap_or_else(|e| panic!("case {case}: k={k} q={q}: {e}"));
        // Stage-2 group count q^{k-1}(q-1).
        assert_eq!(
            d.transversal_groups().len(),
            q.pow(k as u32 - 1) * (q - 1),
            "case {case}: k={k} q={q}"
        );
    }
}

#[test]
fn prop_placement_storage_exact() {
    let mut rng = SplitMix64::new(0xBEE);
    for case in 0..50 {
        let (k, q) = draw_kq(&mut rng);
        let gamma = rng.range(1, 5);
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let d = ResolvableDesign::new(k, q).unwrap();
        let p = Placement::new(&d, &cfg).unwrap();
        p.validate().unwrap_or_else(|e| panic!("case {case}: k={k} q={q} γ={gamma}: {e}"));
        let rep = audit_storage(&p, &cfg).unwrap();
        assert!(
            (rep.measured_mu - rep.expected_mu).abs() < 1e-12,
            "case {case}: k={k} q={q} γ={gamma}"
        );
    }
}

#[test]
fn prop_lemma2_exchange_decodes_for_random_groups() {
    let mut rng = SplitMix64::new(0xC0DE);
    for case in 0..80 {
        let g = rng.range(2, 8);
        let chunk_len = rng.range(1, 300);
        let members: Vec<usize> = (0..g).map(|i| i * 7 + 3).collect();
        let chunks: Vec<ChunkSpec> = (0..g)
            .map(|p| ChunkSpec { receiver: members[p], job: p, func: p, batch: 0 })
            .collect();
        let plan = GroupPlan { members, chunks };
        // Random payloads per chunk.
        let payloads: Vec<Vec<u8>> = (0..g)
            .map(|p| {
                let mut r = SplitMix64::new((case * 100 + p) as u64);
                (0..chunk_len).map(|_| r.next_u64() as u8).collect()
            })
            .collect();
        let deltas: Vec<Vec<u8>> = (0..g)
            .map(|t| plan.encode(t, chunk_len, |p| Ok(payloads[p].clone())).unwrap())
            .collect();
        for r in 0..g {
            let got = plan.decode(r, chunk_len, &deltas, |p| Ok(payloads[p].clone())).unwrap();
            assert_eq!(got, payloads[r], "case {case}: g={g} B={chunk_len} member {r}");
        }
        // Lemma-2 cost.
        let total: usize = deltas.iter().map(|d| d.len()).sum();
        assert_eq!(total, g * chunk_len.div_ceil(g - 1));
    }
}

/// Coding correctness (Lemma 2), exhaustively over the group/chunk grid
/// the issue calls out: every group size g in 2..=8 and chunk sizes
/// including 0, 1, and non-multiples of 8. Encode through the pooled
/// in-place path, decode through the pooled-scratch path, and require
/// every member to recover its missing chunk byte-exactly.
#[test]
fn prop_algorithm2_roundtrip_all_group_and_chunk_sizes() {
    let pool = BufferPool::new();
    for g in 2usize..=8 {
        for chunk_len in [0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 31, 33, 63, 64, 65, 100] {
            let members: Vec<usize> = (0..g).map(|i| i * 5 + 2).collect();
            let chunks: Vec<ChunkSpec> = (0..g)
                .map(|p| ChunkSpec { receiver: members[p], job: p, func: p, batch: 0 })
                .collect();
            let plan = GroupPlan { members, chunks };
            let payloads: Vec<Vec<u8>> = (0..g)
                .map(|p| {
                    let mut r = SplitMix64::new((g * 1000 + p * 10 + chunk_len) as u64);
                    (0..chunk_len).map(|_| r.next_u64() as u8).collect()
                })
                .collect();
            let plen = packet::packet_len(chunk_len, plan.parts());
            // Encode every member's Δ into a pooled buffer.
            let deltas: Vec<camr::shuffle::SharedBuf> = (0..g)
                .map(|t| {
                    let mut b = pool.acquire(plen);
                    plan.encode_ref_into(
                        t,
                        chunk_len,
                        |p| Ok(payloads[p].as_slice()),
                        b.as_mut_slice(),
                    )
                    .unwrap();
                    b.into()
                })
                .collect();
            // Every member decodes its missing chunk with pooled scratch.
            for r in 0..g {
                let got = plan
                    .decode_ref_pooled(
                        r,
                        chunk_len,
                        &deltas,
                        |p| Ok(payloads[p].as_slice()),
                        &pool,
                    )
                    .unwrap();
                assert_eq!(got, payloads[r], "g={g} B={chunk_len} member {r}");
            }
            // Lemma 2's cost: g broadcasts of ⌈B/(g-1)⌉ bytes.
            let total: usize = deltas.iter().map(|d| d.len()).sum();
            assert_eq!(total, g * chunk_len.div_ceil(g - 1), "g={g} B={chunk_len}");
        }
    }
    let stats = pool.stats();
    assert_eq!(stats.outstanding(), 0, "property sweep leaked buffers: {stats:?}");
    assert_eq!(stats.acquired, stats.released);
    assert!(stats.recycled > 0);
}

/// The word-wise XOR primitives agree bit-for-bit with the naive
/// per-byte reference on random data, for lengths spanning the tail
/// cases (0, 1, non-multiples of 8, exact multiples, large).
#[test]
fn prop_xor_wordwise_agrees_with_bytewise_reference() {
    let mut rng = SplitMix64::new(0x0F0F);
    for case in 0..200 {
        let len = match case % 4 {
            0 => rng.range(0, 9),           // tail-only
            1 => rng.range(0, 4) * 8,       // whole words
            2 => rng.range(9, 120),         // mixed
            _ => rng.range(1000, 5000),     // large
        };
        let a: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let b: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut word = a.clone();
        let mut byte = a.clone();
        buf::xor_into(&mut word, &b).unwrap();
        buf::xor_into_bytewise(&mut byte, &b).unwrap();
        assert_eq!(word, byte, "case {case}: len={len}");
        // xor_fold == repeated xor_into_bytewise.
        let c: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut folded = a.clone();
        buf::xor_fold(&mut folded, &[&b, &c]).unwrap();
        let mut reference = a.clone();
        buf::xor_into_bytewise(&mut reference, &b).unwrap();
        buf::xor_into_bytewise(&mut reference, &c).unwrap();
        assert_eq!(folded, reference, "case {case}: fold len={len}");
        // Involution: xoring twice restores the original.
        buf::xor_into(&mut word, &b).unwrap();
        assert_eq!(word, a, "case {case}: xor not an involution");
    }
}

/// Every kernel tier the CPU offers (portable u64, AVX2, NEON — see
/// `buf::available_kernels`) agrees bit-for-bit with the bytewise
/// oracle on random data, including misaligned slices carved out of
/// larger buffers at every sub-word offset — the shape the encode path
/// produces when it XORs packets at arbitrary `idx·plen` offsets.
#[test]
fn prop_every_kernel_tier_agrees_on_random_misaligned_slices() {
    let mut rng = SplitMix64::new(0x51AD);
    let kernels = buf::available_kernels();
    for case in 0..150 {
        let len = match case % 4 {
            0 => rng.range(0, 9),            // tail-only
            1 => rng.range(0, 33) * 8,       // whole words
            2 => rng.range(0, 5) * 128 + 96, // SIMD unroll strides
            _ => rng.range(0, 5000),         // anything
        };
        let off = rng.range(0, 9); // sub-word misalignment
        let a: Vec<u8> = (0..len + off + 8).map(|_| rng.next_u64() as u8).collect();
        let b: Vec<u8> = (0..len + off + 8).map(|_| rng.next_u64() as u8).collect();
        let mut want = a.clone();
        buf::xor_into_bytewise(&mut want[off..off + len], &b[off..off + len]).unwrap();
        for &kernel in &kernels {
            let mut got = a.clone();
            buf::xor_into_with(kernel, &mut got[off..off + len], &b[off..off + len]).unwrap();
            assert_eq!(got, want, "case {case}: kernel={} len={len} off={off}", kernel.label());
        }
    }
}

/// The dispatched `xor_into` uses a kernel the CPU actually has, the
/// decision is stable across calls, and Δ round-trips built through the
/// dispatched path cancel exactly (encode-then-decode is the identity)
/// — so ledger bytes cannot depend on which tier dispatch picked.
#[test]
fn prop_dispatch_is_stable_and_roundtrips() {
    let kernels = buf::available_kernels();
    let active = buf::active_kernel();
    assert!(kernels.contains(&active), "dispatched kernel {:?} unavailable", active);
    assert_eq!(buf::active_kernel(), active, "dispatch decision must be cached");
    let mut rng = SplitMix64::new(0xDE1A);
    for case in 0..50 {
        let len = rng.range(1, 4096);
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mask: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut delta = payload.clone();
        buf::xor_into(&mut delta, &mask).unwrap(); // encode
        buf::xor_into(&mut delta, &mask).unwrap(); // decode cancels
        assert_eq!(delta, payload, "case {case}: len={len}");
    }
}

/// Baseline ordering on the (q, k) grid (Table III / §V): the closed
/// forms must satisfy L_CAMR == L_CCDC < L_uncoded, and CAMR's job
/// requirement q^(k-1) must not exceed CCDC's C(K, μK+1) — guarding
/// `analysis::load` / `analysis::jobs` against refactor drift.
#[test]
fn prop_baseline_ordering_holds_on_qk_grid() {
    for k in 2usize..=6 {
        for q in 2usize..=8 {
            let camr = load::camr_total(k, q);
            let ccdc = load::ccdc_total(k - 1, k * q);
            let uncoded = load::uncoded_aggregated_total(k, q);
            assert!(
                (camr - ccdc).abs() < 1e-12,
                "k={k} q={q}: L_CAMR {camr} != L_CCDC {ccdc}"
            );
            if k >= 3 {
                assert!(camr < uncoded, "k={k} q={q}: {camr} !< {uncoded}");
            } else {
                // k = 2 splits chunks into a single packet: no coding
                // gain, the schemes coincide.
                assert!((camr - uncoded).abs() < 1e-12, "k=2 q={q}");
            }
            // Raw (unaggregated) shuffle is strictly worse still.
            assert!(uncoded < load::uncoded_raw_total(k, q, 2), "k={k} q={q}: raw");
            // Job-count requirement (Table III): q^(k-1) <= C(kq, k).
            let req = jobs::JobRequirement::for_params(k, q);
            assert!(
                req.camr <= req.ccdc,
                "k={k} q={q}: CAMR needs {} jobs > CCDC's {}",
                req.camr,
                req.ccdc
            );
            assert_eq!(req.camr, (q as u128).pow(k as u32 - 1));
            assert_eq!(req.ccdc, jobs::binomial((k * q) as u64, k as u64));
        }
    }
}

#[test]
fn prop_measured_load_matches_formula_when_divisible() {
    let mut rng = SplitMix64::new(0x10AD);
    for case in 0..25 {
        let (k, q) = draw_kq(&mut rng);
        let gamma = rng.range(1, 4);
        // Choose B = (k-1) * 8 * r so packets split exactly and u64
        // lanes stay aligned.
        let bytes = (k - 1) * 8 * rng.range(1, 5);
        let cfg = SystemConfig::with_options(k, q, gamma, 1, bytes).unwrap();
        let wl = SyntheticWorkload::new(&cfg, case as u64);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified, "case {case}: k={k} q={q} γ={gamma} B={bytes}");
        let expect = load::camr_total(k, q);
        assert!(
            (out.total_load() - expect).abs() < 1e-12,
            "case {case}: k={k} q={q} γ={gamma} B={bytes}: {} vs {expect}",
            out.total_load()
        );
        // Per-stage too.
        let forms = load::camr_stages(k, q);
        for (i, f) in [forms.stage1, forms.stage2, forms.stage3].iter().enumerate() {
            assert!(
                (out.stage_load(i + 1) - f).abs() < 1e-12,
                "case {case}: stage {}",
                i + 1
            );
        }
    }
}

#[test]
fn prop_aggregator_laws_random_values() {
    let mut rng = SplitMix64::new(0xA66);
    for case in 0..200 {
        let lanes_n = rng.range(1, 9);
        let len = lanes_n * 8;
        let draw = |r: &mut SplitMix64| -> Vec<u8> {
            (0..len).map(|_| r.next_u64() as u8).collect()
        };
        let a = draw(&mut rng);
        let b = draw(&mut rng);
        let c = draw(&mut rng);
        for agg in [&SumU64 as &dyn Aggregator, &MaxU64, &XorBytes] {
            let ab = agg.combine(&a, &b).unwrap();
            let ba = agg.combine(&b, &a).unwrap();
            assert_eq!(ab, ba, "case {case}: {} commutativity", agg.name());
            let ab_c = agg.combine(&ab, &c).unwrap();
            let a_bc = agg.combine(&a, &agg.combine(&b, &c).unwrap()).unwrap();
            assert_eq!(ab_c, a_bc, "case {case}: {} associativity", agg.name());
            let id = agg.identity(len);
            assert_eq!(agg.combine(&a, &id).unwrap(), a, "case {case}: {} identity", agg.name());
        }
        // f32 commutativity (exact) — associativity is approximate.
        let fa =
            lanes::from_f32(&(0..lanes_n * 2).map(|i| i as f32 * 0.5 - 3.0).collect::<Vec<_>>());
        let fb =
            lanes::from_f32(&(0..lanes_n * 2).map(|i| 1.0 / (i as f32 + 1.0)).collect::<Vec<_>>());
        assert_eq!(
            SumF32.combine(&fa, &fb).unwrap(),
            SumF32.combine(&fb, &fa).unwrap(),
            "case {case}: sum_f32 commutativity"
        );
    }
}

#[test]
fn prop_stage2_groups_pin_unique_jobs() {
    let mut rng = SplitMix64::new(0x57A6E2);
    for _ in 0..30 {
        let (k, q) = draw_kq(&mut rng);
        let d = ResolvableDesign::new(k, q).unwrap();
        for g in d.transversal_groups() {
            let mut seen = std::collections::HashSet::new();
            for i in 0..k {
                let (job, rem) = d.stage2_target(&g, i);
                // Each excluded member maps to a distinct (member, job).
                assert!(seen.insert((g[i], job)));
                assert!(d.owns(rem, job));
                assert!(!d.owns(g[i], job));
            }
        }
    }
}

#[test]
fn prop_total_load_matches_closed_form_on_qk_grid() {
    // Deterministic sweep over a small (q, k) grid: the measured total
    // load must equal (k(q-1)+1)/(q(k-1)) within 1e-9. B is chosen as a
    // multiple of 8(k-1) so packets split exactly (u64 lanes, no
    // padding slack).
    for k in 2..=4usize {
        for q in 2..=4usize {
            let bytes = (k - 1) * 8 * 2;
            let cfg = SystemConfig::with_options(k, q, 2, 1, bytes).unwrap();
            let wl = SyntheticWorkload::new(&cfg, (k * 31 + q) as u64);
            let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
            let out = e.run().unwrap();
            assert!(out.verified, "k={k} q={q}");
            let expect = (k as f64 * (q as f64 - 1.0) + 1.0) / (q as f64 * (k as f64 - 1.0));
            assert!(
                (out.total_load() - expect).abs() < 1e-9,
                "k={k} q={q}: measured {} expected {expect}",
                out.total_load()
            );
        }
    }
}

#[test]
fn prop_parallel_stage_bytes_identical_to_serial() {
    // For random (k, q, γ, B, seed): the thread-per-worker engine's
    // per-stage byte ledger must be byte-identical to the serial
    // engine's for the same seed and workload, and both must verify.
    let mut rng = SplitMix64::new(0x9A7A11E1);
    for case in 0..12 {
        let (k, q) = draw_kq(&mut rng);
        let gamma = rng.range(1, 4);
        let bytes = (k - 1) * 8 * rng.range(1, 4);
        let seed = rng.next_u64();
        let cfg = SystemConfig::with_options(k, q, gamma, 1, bytes).unwrap();
        let sout = {
            let wl = SyntheticWorkload::new(&cfg, seed);
            let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
            e.run().unwrap()
        };
        let pout = {
            let wl = SyntheticWorkload::new(&cfg, seed);
            let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
            e.run().unwrap()
        };
        assert!(sout.verified && pout.verified, "case {case}: k={k} q={q}");
        assert_eq!(
            sout.stage_bytes, pout.stage_bytes,
            "case {case}: k={k} q={q} γ={gamma} B={bytes} seed={seed:#x}"
        );
        assert_eq!(sout.map_invocations, pout.map_invocations, "case {case}");
    }
}

#[test]
fn prop_k2_degenerate_designs_work_end_to_end() {
    // k = 2: single-packet chunks, q^0 = 1-job blocks; the full pipeline
    // must still verify for a range of q.
    for q in [2usize, 3, 5, 8, 11] {
        let cfg = SystemConfig::with_options(2, q, 2, 1, 64).unwrap();
        let wl = SyntheticWorkload::new(&cfg, q as u64);
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified, "q={q}");
        assert!((out.total_load() - load::camr_total(2, q)).abs() < 1e-12);
    }
}
