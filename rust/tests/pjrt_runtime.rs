//! PJRT runtime integration: load the AOT JAX/Pallas artifact, execute
//! it from rust, and run the full engine with the PJRT-backed mapper.
//!
//! These tests need the crate to be built with the `pjrt` feature (which
//! requires the external `xla` dependency) and `make artifacts` to have
//! produced `artifacts/map_kernel.hlo.txt`; without the feature the whole
//! file compiles to nothing, and without the artifact each test skips
//! with a message so `cargo test` works pre-build too.
#![cfg(feature = "pjrt")]

use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::runtime::{meta_path_for, PjrtService, PjrtShardCompute};
use camr::workload::matvec::{MatVecWorkload, NativeShardCompute, ShardCompute};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn artifact() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/map_kernel.hlo.txt");
    if p.exists() && meta_path_for(&p).exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/map_kernel.hlo.txt not built (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_matvec_matches_native() {
    let Some(path) = artifact() else { return };
    let svc = PjrtService::start(&path).unwrap();
    let (m, cols) = (svc.meta().m, svc.meta().cols);
    // Deterministic inputs.
    let a: Vec<f32> = (0..m * cols).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
    let x: Vec<f32> = (0..cols).map(|i| (i as f32 + 1.0) * 0.25).collect();
    let got = svc.matvec(&a, &x).unwrap();
    let want = NativeShardCompute.partial_product(&a, &x, m).unwrap();
    assert_eq!(got.len(), m);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-4 * 1.0f32.max(w.abs()), "{g} vs {w}");
    }
}

#[test]
fn pjrt_rejects_wrong_shapes() {
    let Some(path) = artifact() else { return };
    let svc = PjrtService::start(&path).unwrap();
    let cols = svc.meta().cols;
    assert!(svc.matvec(&[0f32; 4], &vec![0f32; cols]).is_err());
    assert!(svc.matvec(&vec![0f32; svc.meta().m * cols], &[0f32; 1]).is_err());
}

#[test]
fn pjrt_service_survives_many_calls() {
    let Some(path) = artifact() else { return };
    let svc = PjrtService::start(&path).unwrap();
    let (m, cols) = (svc.meta().m, svc.meta().cols);
    let a = vec![0.5f32; m * cols];
    let x = vec![2.0f32; cols];
    for _ in 0..50 {
        let y = svc.matvec(&a, &x).unwrap();
        assert!((y[0] - cols as f32).abs() < 1e-4);
    }
}

#[test]
fn pjrt_service_usable_from_many_threads() {
    let Some(path) = artifact() else { return };
    let svc = Arc::new(PjrtService::start(&path).unwrap());
    let (m, cols) = (svc.meta().m, svc.meta().cols);
    std::thread::scope(|s| {
        for t in 0..8 {
            let svc = Arc::clone(&svc);
            s.spawn(move || {
                let a = vec![t as f32 * 0.1; m * cols];
                let x = vec![1.0f32; cols];
                let y = svc.matvec(&a, &x).unwrap();
                assert!((y[0] - t as f32 * 0.1 * cols as f32).abs() < 1e-3);
            });
        }
    });
}

#[test]
fn full_engine_with_pjrt_mapper_verifies() {
    // The end-to-end three-layer composition: the engine's map phase
    // calls the AOT Pallas kernel through PJRT for every (job, subfile),
    // the coded shuffle runs byte-exactly, and the reduce matches both
    // the PJRT oracle and a pure-rust ground truth.
    let Some(path) = artifact() else { return };
    let compute = PjrtShardCompute::new(&path).unwrap();
    let (m, cols) = compute.shape();
    let cfg = SystemConfig::with_options(3, 2, 2, 1, 64).unwrap();
    let rows_per_func = cfg.value_bytes / 4;
    assert_eq!(m, cfg.functions() * rows_per_func, "artifact matches config");
    let wl =
        MatVecWorkload::synthetic(&cfg, 0xE2E, rows_per_func, cols, Arc::new(compute)).unwrap();
    let truth: Vec<Vec<f32>> = (0..cfg.jobs()).map(|j| wl.full_product(j)).collect();
    let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);
    assert!((out.total_load() - 1.0).abs() < 1e-12);
    for (j, t) in truth.iter().enumerate() {
        for f in 0..cfg.functions() {
            let got = camr::agg::lanes::as_f32(e.output(j, f).unwrap());
            let want = &t[f * rows_per_func..(f + 1) * rows_per_func];
            for (g, w) in got.iter().zip(want) {
                assert!((g - w).abs() < 2e-4 * 1.0f32.max(w.abs()));
            }
        }
    }
}

#[test]
fn batch_agg_artifact_exists_and_parses() {
    // The fused map+combine artifact (L2's map_batch) is also exported.
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/batch_agg.hlo.txt");
    if !p.exists() {
        eprintln!("skipping: batch_agg artifact not built");
        return;
    }
    let text = std::fs::read_to_string(&p).unwrap();
    assert!(text.contains("HloModule"));
    let meta = std::fs::read_to_string(meta_path_for(&p)).unwrap();
    assert!(meta.contains("pallas_matvec+sum"));
}
