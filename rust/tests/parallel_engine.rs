//! Serial vs thread-per-worker engine equivalence, plus concurrency
//! determinism: for the same config and workload seed the two engines
//! must produce byte-identical shared-link ledgers (same transmissions,
//! same order, same byte counts), identical verified outputs, and the
//! parallel engine must be deterministic across repeated runs — any
//! data race in the channel-backed bus or worker stores shows up here.

use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::net::{Bus, Stage};
use camr::workload::synth::SyntheticWorkload;
use camr::workload::wordcount::WordCountWorkload;
use camr::workload::Workload;

/// The full ledger as comparable values: (stage, sender, recipients, bytes).
fn fingerprint(bus: &Bus) -> Vec<(Stage, usize, Vec<usize>, usize)> {
    bus.ledger()
        .iter()
        .map(|t| (t.stage, t.sender, t.recipients.clone(), t.bytes))
        .collect()
}

/// All reduced outputs in deterministic (job, func) order.
fn outputs_of(
    cfg: &SystemConfig,
    get: impl Fn(usize, usize) -> Option<Vec<u8>>,
) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    for j in 0..cfg.jobs() {
        for f in 0..cfg.functions() {
            out.push(get(j, f).expect("output present"));
        }
    }
    out
}

#[test]
fn ledgers_byte_identical_across_configs() {
    // Example 1 plus three more (q, k) points, as the acceptance bar asks.
    for (k, q, gamma, seed) in [
        (3usize, 2usize, 2usize, 0xE1u64), // Example 1 shape
        (2, 3, 1, 0xE2),
        (3, 3, 2, 0xE3),
        (4, 2, 1, 0xE4),
        (2, 5, 2, 0xE5),
    ] {
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let mut serial =
            Engine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, seed))).unwrap();
        let sout = serial.run().unwrap();
        let mut par = ParallelEngine::new(
            cfg.clone(),
            Box::new(SyntheticWorkload::new(&cfg, seed)),
        )
        .unwrap();
        let pout = par.run().unwrap();

        assert!(sout.verified && pout.verified, "k={k} q={q}");
        assert_eq!(sout.stage_bytes, pout.stage_bytes, "k={k} q={q}: stage bytes");
        assert_eq!(
            fingerprint(&serial.bus),
            fingerprint(&par.bus),
            "k={k} q={q}: full ledger (order, senders, recipients, bytes)"
        );
        assert_eq!(sout.map_invocations, pout.map_invocations, "k={k} q={q}");
        let souts = outputs_of(&cfg, |j, f| serial.output(j, f).cloned());
        let pouts = outputs_of(&cfg, |j, f| par.output(j, f).cloned());
        assert_eq!(souts, pouts, "k={k} q={q}: reduced outputs");
    }
}

#[test]
fn parallel_engine_deterministic_over_10_runs() {
    // Same config, same seed, 10 fresh engines: the ledger and every
    // verified output must be identical each time — catches data races
    // in the channel-backed bus and the barrier structure.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let run_once = || {
        let wl = SyntheticWorkload::new(&cfg, 0xD0);
        let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
        let out = e.run().unwrap();
        assert!(out.verified);
        (fingerprint(&e.bus), outputs_of(&cfg, |j, f| e.output(j, f).cloned()))
    };
    let (ledger0, outputs0) = run_once();
    assert!(!ledger0.is_empty());
    for i in 1..10 {
        let (ledger, outputs) = run_once();
        assert_eq!(ledger, ledger0, "run {i}: ledger diverged");
        assert_eq!(outputs, outputs0, "run {i}: outputs diverged");
    }
}

#[test]
fn parallel_wordcount_example1_measures_paper_loads() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = WordCountWorkload::example1(&cfg);
    let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);
    assert_eq!(e.bus.stage_bytes(Stage::Stage1), 6 * cfg.value_bytes);
    assert_eq!(e.bus.stage_bytes(Stage::Stage2), 6 * cfg.value_bytes);
    assert_eq!(e.bus.stage_bytes(Stage::Stage3), 12 * cfg.value_bytes);
    assert!((out.total_load() - 1.0).abs() < 1e-12);
}

#[test]
fn parallel_multi_round_matches_serial() {
    let cfg = SystemConfig::with_options(3, 2, 2, 2, 64).unwrap();
    let mut serial =
        Engine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 5))).unwrap();
    let sout = serial.run().unwrap();
    let mut par =
        ParallelEngine::new(cfg.clone(), Box::new(SyntheticWorkload::new(&cfg, 5))).unwrap();
    let pout = par.run().unwrap();
    assert!(pout.verified);
    assert_eq!(sout.stage_bytes, pout.stage_bytes);
    assert_eq!(fingerprint(&serial.bus), fingerprint(&par.bus));
    assert_eq!(pout.outputs, cfg.jobs() * cfg.functions());
}

/// A workload whose map fails for one subfile — the engine must surface
/// the error instead of deadlocking at a barrier or channel receive.
struct FailingMapWorkload {
    inner: SyntheticWorkload,
}

impl Workload for FailingMapWorkload {
    fn name(&self) -> &str {
        "failing-map"
    }
    fn aggregator(&self) -> &dyn camr::agg::Aggregator {
        self.inner.aggregator()
    }
    fn map_subfile(&self, job: usize, subfile: usize) -> camr::error::Result<Vec<Vec<u8>>> {
        if job == 1 && subfile == 2 {
            return Err(camr::error::CamrError::Runtime("injected map failure".into()));
        }
        self.inner.map_subfile(job, subfile)
    }
}

#[test]
fn map_failure_surfaces_as_error_not_deadlock() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = FailingMapWorkload { inner: SyntheticWorkload::new(&cfg, 8) };
    let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
    let err = e.run().expect_err("run must fail");
    let msg = err.to_string();
    assert!(
        msg.contains("injected map failure") || msg.contains("aborted"),
        "unexpected error: {msg}"
    );
}

#[test]
fn parallel_engine_recovers_after_failed_run() {
    // A failed run must not poison the engine: a subsequent clean run on
    // a fresh engine of the same shape still verifies.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    {
        let wl = FailingMapWorkload { inner: SyntheticWorkload::new(&cfg, 8) };
        let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
        assert!(e.run().is_err());
    }
    let wl = SyntheticWorkload::new(&cfg, 8);
    let mut e = ParallelEngine::new(cfg, Box::new(wl)).unwrap();
    assert!(e.run().unwrap().verified);
}
