//! Smoke tests for the machine-readable bench reports: every
//! `BENCH_*.json` a bench target emits must parse with [`Json::parse`]
//! and carry its identifying `bench` field.
//!
//! Benches are not executed by `cargo test`, so the on-disk checks are
//! conditional: files written by an earlier `cargo bench … -- --quick`
//! run (CI runs one right before re-running this test) are validated,
//! missing ones are skipped. The writer-side shape of each report is
//! additionally pinned here unconditionally, through the exact
//! `Json`-building code path the benches use.

use camr::util::json::Json;
use std::path::PathBuf;

/// Every bench that writes a machine-readable report, with its file.
const BENCH_FILES: &[(&str, &str)] = &[
    ("xor_throughput", "BENCH_shuffle.json"),
    ("sim_sweep", "BENCH_sim.json"),
    ("batch_jobs", "BENCH_batch.json"),
];

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn emitted_bench_reports_parse_as_json() {
    let mut checked = 0usize;
    for (bench, file) in BENCH_FILES {
        let path = repo_path(file);
        let Ok(text) = std::fs::read_to_string(&path) else {
            eprintln!("note: {file} absent (run `cargo bench --bench {bench} -- --quick`)");
            continue;
        };
        let parsed = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{file} is not valid JSON: {e}"));
        assert_eq!(
            parsed.get("bench"),
            Some(&Json::Str(bench.to_string())),
            "{file} must identify its bench"
        );
        checked += 1;
    }
    eprintln!("validated {checked}/{} bench reports", BENCH_FILES.len());
}

#[test]
fn bench_report_shape_parses_before_any_bench_runs() {
    // The exact structure the benches assemble (nested objects, arrays
    // of rows, floats, counters) survives a render → parse round trip
    // byte-for-byte — so a bench emitting through `Json` cannot produce
    // an unparseable file.
    let report = Json::obj(vec![
        ("bench", Json::Str("batch_jobs".into())),
        ("quick", Json::Bool(true)),
        (
            "rows",
            Json::Arr(
                (0..3)
                    .map(|i| {
                        Json::obj(vec![
                            ("scheme", Json::Str("camr".into())),
                            ("rounds", Json::UInt(i as u128 + 1)),
                            ("wall_ns", Json::Num(1.5e6 * (i + 1) as f64)),
                            ("serial_secs", Json::Num(0.0234375)),
                            ("pipelined_secs", Json::Num(0.015625)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let rendered = report.render();
    let parsed = Json::parse(&rendered).expect("report shape parses");
    assert_eq!(parsed.render(), rendered);
    let rows = match parsed.get("rows") {
        Some(Json::Arr(rows)) => rows,
        other => panic!("rows missing: {other:?}"),
    };
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[1].get("rounds"), Some(&Json::UInt(2)));
}
