//! Smoke tests for the machine-readable bench reports: every
//! `BENCH_*.json` a bench target emits must parse with [`Json::parse`]
//! and carry its identifying `bench` field.
//!
//! Benches are not executed by `cargo test`, so the on-disk checks are
//! conditional: files written by an earlier `cargo bench … -- --quick`
//! run (CI runs one right before re-running this test) are validated,
//! missing ones are skipped. The writer-side shape of each report is
//! additionally pinned here unconditionally, through the exact
//! `Json`-building code path the benches use.

use camr::config::RunConfig;
use camr::coordinator::parallel::ParallelEngine;
use camr::metrics::{ServeReport, TenantServe};
use camr::obs::{self, Tracer};
use camr::util::json::Json;
use camr::workload::build_native;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Every bench that writes a machine-readable report, with its file.
const BENCH_FILES: &[(&str, &str)] = &[
    ("xor_throughput", "BENCH_shuffle.json"),
    ("sim_sweep", "BENCH_sim.json"),
    ("batch_jobs", "BENCH_batch.json"),
];

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn emitted_bench_reports_parse_as_json() {
    let mut checked = 0usize;
    for (bench, file) in BENCH_FILES {
        let path = repo_path(file);
        let Ok(text) = std::fs::read_to_string(&path) else {
            eprintln!("note: {file} absent (run `cargo bench --bench {bench} -- --quick`)");
            continue;
        };
        let parsed = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{file} is not valid JSON: {e}"));
        assert_eq!(
            parsed.get("bench"),
            Some(&Json::Str(bench.to_string())),
            "{file} must identify its bench"
        );
        checked += 1;
    }
    eprintln!("validated {checked}/{} bench reports", BENCH_FILES.len());
}

#[test]
fn bench_report_shape_parses_before_any_bench_runs() {
    // The exact structure the benches assemble (nested objects, arrays
    // of rows, floats, counters) survives a render → parse round trip
    // byte-for-byte — so a bench emitting through `Json` cannot produce
    // an unparseable file.
    let report = Json::obj(vec![
        ("bench", Json::Str("batch_jobs".into())),
        ("quick", Json::Bool(true)),
        (
            "rows",
            Json::Arr(
                (0..3)
                    .map(|i| {
                        Json::obj(vec![
                            ("scheme", Json::Str("camr".into())),
                            ("rounds", Json::UInt(i as u128 + 1)),
                            ("wall_ns", Json::Num(1.5e6 * (i + 1) as f64)),
                            ("serial_secs", Json::Num(0.0234375)),
                            ("pipelined_secs", Json::Num(0.015625)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let rendered = report.render();
    let parsed = Json::parse(&rendered).expect("report shape parses");
    assert_eq!(parsed.render(), rendered);
    let rows = match parsed.get("rows") {
        Some(Json::Arr(rows)) => rows,
        other => panic!("rows missing: {other:?}"),
    };
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[1].get("rounds"), Some(&Json::UInt(2)));
}

/// `BENCH_serve.json` is written by the `camr serve --bench` CLI driver
/// rather than a `cargo bench` target, so it gets its own conditional
/// on-disk check (CI runs the quick traffic run right before this).
#[test]
fn emitted_serve_report_parses_as_json() {
    let path = repo_path("BENCH_serve.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!("note: BENCH_serve.json absent (run `camr serve --bench --quick`)");
        return;
    };
    let parsed = Json::parse(&text)
        .unwrap_or_else(|e| panic!("BENCH_serve.json is not valid JSON: {e}"));
    assert_eq!(
        parsed.get("bench"),
        Some(&Json::Str("serve".to_string())),
        "BENCH_serve.json must identify its driver"
    );
    for field in ["jobs_submitted", "jobs_completed", "paper_jobs", "sojourn_p99_us", "tenants"] {
        assert!(parsed.get(field).is_some(), "BENCH_serve.json missing `{field}`");
    }
    let Some(Json::Arr(tenants)) = parsed.get("tenants") else {
        panic!("tenants must be an array");
    };
    assert!(!tenants.is_empty(), "serve report must cover >= 1 tenant");
}

/// The serve report's writer-side shape, pinned unconditionally through
/// the exact `Json`-building path the CLI driver uses.
#[test]
fn serve_report_shape_parses_before_any_traffic_runs() {
    let report = ServeReport {
        k: 2,
        q: 2,
        gamma: 1,
        value_bytes: 16,
        servers: 4,
        engines: 2,
        parallel: false,
        quick: true,
        queue_capacity: 64,
        jobs_submitted: 100_000,
        jobs_completed: 100_000,
        jobs_rejected: 17,
        paper_jobs: 200_000,
        verified: true,
        wall_secs: 12.5,
        jobs_per_sec: 8000.0,
        sojourn_us: [400, 900],
        sojourn_mean_us: 450.25,
        queue_us: [350, 800],
        exec_us: [50, 120],
        tenants: (0..4)
            .map(|tenant| TenantServe {
                tenant,
                weight: tenant as u64 + 1,
                submitted: 25_000,
                completed: 25_000,
                rejected: 4,
            })
            .collect(),
    };
    let rendered = report.to_json();
    let parsed = Json::parse(&rendered).expect("serve report shape parses");
    assert_eq!(parsed.render(), rendered);
    assert_eq!(parsed.get("bench"), Some(&Json::Str("serve".into())));
    assert_eq!(parsed.get("paper_jobs"), Some(&Json::UInt(200_000)));
    assert_eq!(parsed.get("sojourn_p99_us"), Some(&Json::UInt(900)));
    let Some(Json::Arr(tenants)) = parsed.get("tenants") else {
        panic!("tenants must be an array");
    };
    assert_eq!(tenants.len(), 4);
    assert_eq!(tenants[3].get("weight"), Some(&Json::UInt(4)));
}

/// A trace written by `obs::write_chrome_trace` must be a valid Chrome
/// `trace_event` document: parseable by [`Json::parse`], every event
/// carrying `ph`/`ts`/`pid`/`tid`/`name`, and B/E events paired per
/// thread lane — the schema Perfetto and chrome://tracing load.
#[test]
fn chrome_trace_export_is_schema_valid() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/example1.toml");
    let rc = RunConfig::from_path(&path).expect("configs/example1.toml parses");
    let wl = build_native(rc.workload, &rc.system, rc.seed).unwrap();
    let mut e = ParallelEngine::new(rc.system, wl).unwrap();
    e.tracer = Tracer::on();
    let out = e.run().unwrap();
    assert!(out.verified);
    let spans = e.tracer.take_spans();
    assert!(!spans.is_empty(), "traced run produced no spans");

    let dest = std::env::temp_dir().join(format!("camr_trace_test_{}.json", std::process::id()));
    obs::write_chrome_trace(&dest, &spans).unwrap();
    let text = std::fs::read_to_string(&dest).unwrap();
    let _ = std::fs::remove_file(&dest);

    let parsed = Json::parse(&text).expect("trace.json parses");
    let events = match parsed.get("traceEvents") {
        Some(Json::Arr(events)) => events,
        other => panic!("traceEvents missing: {other:?}"),
    };
    assert_eq!(events.len(), spans.len() * 2, "one B and one E per span");

    // Per-lane B/E pairing: the begin/end counts must match on every
    // tid, and a lane's nesting depth can never go negative when events
    // are scanned in file order (chrome_trace emits them sorted).
    let mut depth: BTreeMap<String, i64> = BTreeMap::new();
    for ev in events {
        for field in ["ph", "ts", "pid", "tid", "name"] {
            assert!(ev.get(field).is_some(), "event missing `{field}`: {ev:?}");
        }
        let tid = ev.get("tid").unwrap().render();
        let d = depth.entry(tid.clone()).or_insert(0);
        match ev.get("ph") {
            Some(Json::Str(ph)) if ph == "B" => *d += 1,
            Some(Json::Str(ph)) if ph == "E" => {
                *d -= 1;
                assert!(*d >= 0, "lane {tid}: E without a matching B");
            }
            other => panic!("unexpected ph: {other:?}"),
        }
    }
    for (tid, d) in depth {
        assert_eq!(d, 0, "lane {tid}: unbalanced B/E events");
    }
}
