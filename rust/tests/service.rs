//! Integration suite for the continuous job service
//! (`camr::service`): deterministic per-tenant fairness through the
//! live dispatcher, backpressure bounds with typed rejections, graceful
//! drain (no lost or double-run jobs), byte-exact ledgers through the
//! service path, and the seeded Poisson arrival trace the open-arrival
//! mode shares with the simulator.
//!
//! The fairness test needs every lane backlogged before the dispatcher
//! pops — a race against a live thread — so it verifies the
//! precondition under the service lock (`queue_len()` right after the
//! burst) and retries the whole experiment on the rare miss. Once the
//! precondition holds, the deficit round-robin pop order is exact, not
//! statistical.

use camr::config::{RunConfig, SystemConfig, WorkloadKind};
use camr::error::CamrError;
use camr::net::Transmission;
use camr::obs::{SpanKind, Tracer};
use camr::service::{JobService, JobSpec, ServiceOptions};
use camr::sim::{poisson_trace, simulate_open_arrivals, ArrivalConfig};
use std::path::PathBuf;

/// Smallest legal CAMR system: k=2, q=2 → K=4 servers, J=2 jobs.
fn tiny_cfg() -> SystemConfig {
    SystemConfig::with_options(2, 2, 1, 1, 16).unwrap()
}

fn spec(tenant: usize, seed: u64) -> JobSpec {
    JobSpec { tenant, kind: WorkloadKind::Synthetic, seed }
}

#[test]
fn fairness_shares_follow_drr_weights_through_the_service() {
    // Weights 1:2, quantum 1, one engine. With lane 0 holding 3 jobs
    // and lane 1 holding 6 while both stay backlogged, DRR serves the
    // warm-up job then exactly [1,1,0, 1,1,0, 1,1,0].
    let mut pinned = false;
    for attempt in 0..20 {
        let svc = JobService::start(
            tiny_cfg(),
            ServiceOptions {
                engines: 1,
                weights: vec![1, 2],
                queue_capacity: 64,
                ..ServiceOptions::default()
            },
        )
        .unwrap();
        let warm = svc.submit(spec(0, 1000)).unwrap();
        for i in 0..3 {
            svc.submit(spec(0, 2000 + i)).unwrap();
        }
        for i in 0..6 {
            svc.submit(spec(1, 3000 + i)).unwrap();
        }
        // Precondition, checked under the service lock: at most the
        // warm-up job was popped (and a first pop always takes it —
        // lane 0 is FIFO and the cursor starts there with credit).
        let backlogged = svc.queue_len() >= 9;
        let out = svc.drain().unwrap();
        assert_eq!(out.completed(), 10, "attempt {attempt} lost jobs");
        assert!(out.all_verified());
        if !backlogged {
            continue; // the dispatcher raced the burst; try again
        }
        assert_eq!(out.results[0].job, warm);
        let order: Vec<usize> = out.results[1..].iter().map(|r| r.tenant).collect();
        assert_eq!(order, vec![1, 1, 0, 1, 1, 0, 1, 1, 0], "DRR pop order drifted");
        pinned = true;
        break;
    }
    assert!(pinned, "never queued the full burst before the dispatcher popped");
}

#[test]
fn backpressure_bounds_the_queue_with_typed_rejections() {
    let capacity = 1usize;
    let svc = JobService::start(
        tiny_cfg(),
        ServiceOptions {
            engines: 1,
            weights: vec![1],
            queue_capacity: capacity,
            ..ServiceOptions::default()
        },
    )
    .unwrap();
    // Burst non-blocking submits until one bounces; with a capacity-1
    // lane and microsecond pushes against millisecond-scale wakeups the
    // bound is hit almost immediately.
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for i in 0..10_000u64 {
        match svc.submit(spec(0, i)) {
            Ok(_) => accepted += 1,
            Err(CamrError::QueueFull(msg)) => {
                assert!(msg.contains("capacity 1"), "typed reject carries the bound: {msg}");
                rejected += 1;
                break;
            }
            Err(other) => panic!("expected QueueFull, got {other}"),
        }
        assert!(svc.queue_len() <= capacity, "queue exceeded its bound");
    }
    assert!(rejected > 0, "never hit the capacity bound after 10k submits");
    // The blocking flavor waits for space instead of bouncing.
    let blocked = svc.submit_blocking(spec(0, 77_777)).unwrap();
    accepted += 1;
    let out = svc.drain().unwrap();
    assert_eq!(out.submitted, accepted, "admission count drifted");
    assert_eq!(out.completed() as u64, accepted, "drain lost admitted jobs");
    assert!(out.results.iter().any(|r| r.job == blocked));
    // Both the bounced submit and the blocking submit's full-lane
    // encounter count as backpressure events.
    assert!(out.rejected >= rejected, "typed rejections not counted");
    assert!(out.all_verified());
}

#[test]
fn graceful_drain_runs_every_job_exactly_once_across_engines() {
    let svc = JobService::start(
        tiny_cfg(),
        ServiceOptions {
            engines: 3,
            weights: vec![1, 2, 3],
            queue_capacity: 8,
            ..ServiceOptions::default()
        },
    )
    .unwrap();
    let jobs = 120u64;
    for j in 0..jobs {
        svc.submit_blocking(spec((j % 3) as usize, j)).unwrap();
    }
    let out = svc.drain().unwrap();
    assert_eq!(out.submitted, jobs);
    assert_eq!(out.completed() as u64, jobs, "drain lost queued jobs");
    // Exactly once: ids are a permutation of the admission sequence.
    let mut ids: Vec<u64> = out.results.iter().map(|r| r.job).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..jobs).collect::<Vec<_>>(), "a job was lost or double-run");
    assert!(out.all_verified(), "an engine round failed oracle verification");
    // Every dispatcher actually served traffic, and per-tenant
    // accounting adds back up.
    let engines: std::collections::BTreeSet<usize> =
        out.results.iter().map(|r| r.engine).collect();
    assert_eq!(engines.len(), 3, "a dispatcher sat idle through 120 jobs");
    let per = out.per_tenant();
    assert_eq!(per.iter().map(|t| t.completed).sum::<u64>(), jobs);
    assert_eq!(per[0].completed, 40);
    assert_eq!(per[1].completed, 40);
    assert_eq!(per[2].completed, 40);
    // Sojourn decomposition is internally consistent.
    for r in &out.results {
        assert_eq!(r.sojourn_ns(), r.queue_ns + r.exec_ns);
        assert!(r.exec_ns > 0, "round cannot take zero time");
        assert!(r.error.is_none());
    }
}

/// Render a captured ledger in the golden fixture's line format
/// (`<stage> <sender> <bytes> <recipient,...>` — see
/// `rust/tests/golden_ledger.rs`).
fn render(ledger: &[Transmission]) -> String {
    let mut out = String::new();
    for t in ledger {
        let recipients: Vec<String> = t.recipients.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!("{} {} {} {}\n", t.stage, t.sender, t.bytes, recipients.join(",")));
    }
    out
}

/// The golden fixture's data lines (comments stripped).
fn fixture_contents() -> String {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/example1_ledger.txt");
    let text = std::fs::read_to_string(path).expect("golden fixture present");
    let mut out = String::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push_str(line);
        out.push('\n');
    }
    out
}

#[test]
fn service_path_ledger_matches_the_golden_fixture() {
    // The ledger is payload-independent (sizes + routing only), so a
    // word-count round at the Example 1 config must reproduce the
    // fixture byte-for-byte even through admission and dispatch — on
    // both engine flavors.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/example1.toml");
    let rc = RunConfig::from_path(&path).expect("configs/example1.toml parses");
    for parallel in [false, true] {
        let svc = JobService::start(
            rc.system.clone(),
            ServiceOptions {
                engines: 1,
                parallel,
                weights: vec![1],
                capture_ledger: true,
                ..ServiceOptions::default()
            },
        )
        .unwrap();
        svc.submit(JobSpec { tenant: 0, kind: WorkloadKind::WordCount, seed: rc.seed }).unwrap();
        let out = svc.drain().unwrap();
        assert_eq!(out.completed(), 1);
        assert!(out.all_verified());
        assert_eq!(
            render(&out.results[0].ledger),
            fixture_contents(),
            "service-path ledger drifted from the golden fixture (parallel={parallel})"
        );
        let bytes: usize = out.results[0].ledger.iter().map(|t| t.bytes).sum();
        assert_eq!(out.results[0].bytes, bytes, "JobResult.bytes disagrees with its ledger");
    }
}

#[test]
fn queue_wait_spans_and_phase_rollups_reach_the_service_tracer() {
    let tracer = Tracer::on();
    let svc = JobService::start(
        tiny_cfg(),
        ServiceOptions {
            engines: 1,
            weights: vec![1],
            tracer: tracer.clone(),
            ..ServiceOptions::default()
        },
    )
    .unwrap();
    for j in 0..4u64 {
        svc.submit_blocking(spec(0, j)).unwrap();
    }
    let out = svc.drain().unwrap();
    assert!(out.all_verified());
    let spans = tracer.take_spans();
    let queue_spans = spans.iter().filter(|s| s.kind == SpanKind::Queue).count();
    assert_eq!(queue_spans, 4, "one queue-wait span per job");
    assert!(
        spans.iter().any(|s| s.kind == SpanKind::Map),
        "engine spans re-ingested into the service tracer"
    );
    for r in &out.results {
        assert!(!r.phases.is_empty(), "traced jobs carry per-phase roll-ups");
        assert!(
            r.phases.iter().all(|p| p.phase != "queue"),
            "queue waits overlap rounds and must stay out of phase roll-ups"
        );
    }
}

#[test]
fn shipped_serve_config_parses_with_its_service_section() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/serve.toml");
    let rc = RunConfig::from_path(&path).expect("configs/serve.toml parses");
    let svc = rc.service.expect("serve.toml carries a [service] section");
    svc.validate().expect("shipped service section validates");
    assert_eq!(svc.engines, 2);
    assert_eq!(svc.weight_vector(), vec![1, 1, 2, 4]);
    assert_eq!(svc.tenants, 4);
}

#[test]
fn poisson_arrival_trace_is_deterministic_and_replayable() {
    // The trace the serve driver paces real submissions by and the one
    // the simulator replays are the same function of the seed.
    let cfg = ArrivalConfig { rate_per_sec: 250.0, jobs: 500, tenants: 3, seed: 0xCA3A };
    let a = poisson_trace(&cfg).unwrap();
    let b = poisson_trace(&cfg).unwrap();
    assert_eq!(a, b, "same seed must reproduce the arrival trace bit-exactly");
    assert_ne!(a, poisson_trace(&ArrivalConfig { seed: 1, ..cfg }).unwrap());
    let sim = simulate_open_arrivals(&a, 0.001, 2, 3).unwrap();
    assert_eq!(sim.completed, 500);
    assert_eq!(sim.per_tenant_completed.iter().sum::<u64>(), 500);
    assert!(sim.sojourn_p50_secs >= 0.001 - 1e-12, "sojourn includes service time");
    assert!(sim.sojourn_p99_secs >= sim.sojourn_p50_secs);
    // Replays of the same trace are themselves deterministic.
    assert_eq!(simulate_open_arrivals(&a, 0.001, 2, 3).unwrap(), sim);
}
