//! Observability must be a pure observer: running with the tracer on
//! cannot change a single byte of protocol behaviour.
//!
//! The golden fixture `rust/tests/golden/example1_ledger.txt` pins the
//! shared-link ledger of `configs/example1.toml` (paper Example 1).
//! This suite re-runs that config on the serial engine, the channel
//! plane and a Unix-domain socket plane with `Tracer::on()` and asserts
//! each traced ledger is byte-identical to the fixture — and that pool
//! hygiene counters match an untraced run exactly, so tracing adds no
//! buffer traffic either. It also pins the span *coverage* contract:
//! every worker (and the coordinator) shows up in the trace on every
//! plane, including socket workers whose spans travel back to the hub
//! in `Spans` frames with a worker-local epoch.
//!
//! The disabled path gets its own test: a `Tracer::Off` sink must
//! record nothing and hand back nothing.

use camr::config::RunConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::{ParallelEngine, TransportKind};
use camr::coordinator::remote::{SocketOptions, WorkerSpec};
use camr::net::Bus;
use camr::obs::{Span, SpanKind, Tracer, COORD};
use camr::shuffle::buf::PoolStats;
use camr::workload::build_native;
use std::collections::BTreeSet;
use std::path::PathBuf;

fn example1_config() -> RunConfig {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/example1.toml");
    RunConfig::from_path(&path).expect("configs/example1.toml parses")
}

/// Render a ledger in the fixture's line format:
/// `<stage> <sender> <bytes> <recipient,...>`.
fn render(bus: &Bus) -> String {
    let mut out = String::new();
    for t in bus.ledger() {
        let recipients: Vec<String> = t.recipients.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!(
            "{} {} {} {}\n",
            t.stage,
            t.sender,
            t.bytes,
            recipients.join(",")
        ));
    }
    out
}

/// The fixture's data lines (comments stripped), newline-terminated.
fn fixture_contents() -> String {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/example1_ledger.txt");
    let text = std::fs::read_to_string(path).expect("golden fixture exists");
    let mut out = String::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push_str(line);
        out.push('\n');
    }
    out
}

/// One serial run; the tracer is drained so each call stands alone.
fn run_serial(tracer: &Tracer) -> (String, PoolStats, Vec<Span>) {
    let rc = example1_config();
    let wl = build_native(rc.workload, &rc.system, rc.seed).unwrap();
    let mut e = Engine::new(rc.system, wl).unwrap();
    e.tracer = tracer.clone();
    let out = e.run().unwrap();
    assert!(out.verified, "serial run failed verification");
    (render(&e.bus), e.pool_stats(), tracer.take_spans())
}

/// One run over the given parallel-plane transport.
fn run_over(transport: TransportKind, tracer: &Tracer) -> (String, PoolStats, Vec<Span>) {
    let rc = example1_config();
    let wl = build_native(rc.workload, &rc.system, rc.seed).unwrap();
    let mut e = ParallelEngine::new(rc.system, wl).unwrap();
    e.remote_spec = Some(WorkerSpec {
        kind: rc.workload,
        seed: rc.seed,
    });
    e.transport = transport;
    e.tracer = tracer.clone();
    let out = e.run().unwrap();
    assert!(out.verified, "run failed verification");
    (render(&e.bus), e.pool_stats(), tracer.take_spans())
}

/// Worker ids present in a span set, with [`COORD`] kept separate.
fn coverage(spans: &[Span]) -> (BTreeSet<usize>, bool) {
    let mut workers = BTreeSet::new();
    let mut coord = false;
    for s in spans {
        if s.worker == COORD {
            coord = true;
        } else {
            workers.insert(s.worker);
        }
    }
    (workers, coord)
}

fn assert_full_coverage(label: &str, spans: &[Span], servers: usize) {
    let (workers, coord) = coverage(spans);
    assert_eq!(
        workers,
        (0..servers).collect::<BTreeSet<_>>(),
        "{label}: spans missing from some workers"
    );
    assert!(coord, "{label}: no coordinator span (verify)");
    let kinds: BTreeSet<u8> = spans.iter().map(|s| s.kind.code()).collect();
    for kind in [
        SpanKind::Map,
        SpanKind::Encode,
        SpanKind::Exchange,
        SpanKind::Decode,
        SpanKind::Reduce,
        SpanKind::Verify,
    ] {
        assert!(
            kinds.contains(&kind.code()),
            "{label}: no {kind:?} span recorded"
        );
    }
}

#[test]
fn traced_serial_ledger_and_pool_match_untraced() {
    let fixture = fixture_contents();
    let (plain_ledger, plain_pool, no_spans) = run_serial(&Tracer::Off);
    assert!(no_spans.is_empty(), "Tracer::Off produced spans");
    assert_eq!(plain_ledger, fixture, "untraced serial ledger != fixture");

    let tracer = Tracer::on();
    let (ledger, pool, spans) = run_serial(&tracer);
    assert_eq!(ledger, fixture, "traced serial ledger != fixture");
    assert_eq!(pool, plain_pool, "tracing changed pool traffic");
    assert_full_coverage("serial", &spans, example1_config().system.servers());
}

#[test]
fn traced_chan_ledger_and_pool_match_untraced() {
    let fixture = fixture_contents();
    let (plain_ledger, plain_pool, _) = run_over(TransportKind::Chan, &Tracer::Off);
    assert_eq!(plain_ledger, fixture, "untraced chan ledger != fixture");

    let tracer = Tracer::on();
    let (ledger, pool, spans) = run_over(TransportKind::Chan, &tracer);
    assert_eq!(ledger, fixture, "traced chan ledger != fixture");
    assert_eq!(pool, plain_pool, "tracing changed pool traffic");
    assert_full_coverage("chan", &spans, example1_config().system.servers());
}

#[test]
fn traced_socket_ledger_matches_fixture_with_worker_spans() {
    let fixture = fixture_contents();
    let tracer = Tracer::on();
    let (ledger, _, spans) = run_over(
        TransportKind::Socket(SocketOptions::unix_threads()),
        &tracer,
    );
    assert_eq!(ledger, fixture, "traced unix-socket ledger != fixture");
    // Socket-plane spans arrive at the hub in Spans frames sent by each
    // worker between its Outputs and Done frames; full coverage here
    // proves that round trip — the hub never records Map/Reduce itself.
    assert_full_coverage("unix", &spans, example1_config().system.servers());
    assert!(
        spans.iter().any(|s| s.kind.code() == SpanKind::FrameIo.code()),
        "socket plane recorded no frame_io spans"
    );
}

#[test]
fn disabled_tracer_records_nothing() {
    let tracer = Tracer::Off;
    assert!(!tracer.enabled());
    let mut sink = tracer.sink();
    // The Off branch hands back a timestamp-free token; record() must
    // be a no-op rather than an allocation or a clock read.
    let t = sink.begin();
    sink.record(t, SpanKind::Map, 0, 0, None, 0, 0);
    drop(sink);
    assert!(tracer.take_spans().is_empty());

    // Ingesting into a disabled tracer also discards.
    tracer.ingest(vec![]);
    assert!(tracer.take_spans().is_empty());
}

#[test]
fn traced_spans_carry_byte_accounting() {
    let tracer = Tracer::on();
    let (_, _, spans) = run_serial(&tracer);
    // Every encode span ships one coded delta; the byte tags must sum
    // to something positive and every span must close after it opened.
    let encode_bytes: u64 = spans
        .iter()
        .filter(|s| s.kind.code() == SpanKind::Encode.code())
        .map(|s| s.bytes)
        .sum();
    assert!(encode_bytes > 0, "encode spans carry no byte accounting");
    for s in &spans {
        assert!(s.end_ns() >= s.start_ns, "span closed before it opened");
    }
}
