//! Integration suite for the repo-invariant linter
//! (`camr::check::lint`): the real tree lints clean, and each fixture
//! under `rust/tests/lint_fixtures/` — a minimal repo reproducing one
//! defect class this repo has actually shipped or guards against — is
//! flagged with exactly its diagnostic code.

use camr::check::lint::lint_repo;
use std::collections::BTreeSet;
use std::path::PathBuf;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> PathBuf {
    repo_root().join("rust/tests/lint_fixtures").join(name)
}

/// Lint a fixture and assert it produces `expected` errors and nothing
/// else — each fixture isolates exactly one defect class.
fn assert_only(name: &str, expected: &str) {
    let report = lint_repo(&fixture(name)).unwrap();
    assert!(!report.is_clean(), "{name} should fail lint");
    let codes: BTreeSet<&str> = report.errors().iter().map(|d| d.code).collect();
    assert_eq!(
        codes,
        BTreeSet::from([expected]),
        "{name}: {:?}",
        report.diagnostics
    );
}

#[test]
fn real_tree_lints_clean() {
    let report = lint_repo(&repo_root()).unwrap();
    assert!(
        report.is_clean(),
        "the shipped tree must pass its own linter:\n{}",
        report
            .diagnostics
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn unregistered_test_fixture_flagged_l201() {
    // Reproduces the PR 9 defect: a test file on disk that no
    // `[[test]]` entry registers (autotests = false silently skips it).
    assert_only("pr9_unregistered_test", "L201");
}

#[test]
fn bench_name_mismatch_fixture_flagged_l202() {
    // Reproduces the PR 7 defect: a bench emitting a "bench" name the
    // bench_json schema test does not assert.
    assert_only("pr7_bench_name", "L202");
}

#[test]
fn overwide_line_fixture_flagged_l203() {
    assert_only("overwide_line", "L203");
}

#[test]
fn duplicate_frame_kind_fixture_flagged_l204() {
    assert_only("dup_frame_kind", "L204");
}

#[test]
fn duplicate_wire_code_fixture_flagged_l205() {
    assert_only("dup_wire_code", "L205");
}

#[test]
fn sim_wallclock_fixture_flagged_l206() {
    assert_only("sim_wallclock", "L206");
}

#[test]
fn missing_manifest_is_reported_not_panicked() {
    // Linting a directory with no Cargo.toml is an L201 finding (the
    // registration audit cannot run), not an I/O crash.
    let report = lint_repo(&fixture("..")).unwrap();
    assert!(report.has_code("L201"), "{:?}", report.diagnostics);
}
