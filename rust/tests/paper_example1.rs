//! Paper-exact reproduction tests for the running example (K = 6,
//! q = 2, k = 3): Eq. (2) ownership, Fig. 1 placement, Example 3 /
//! Fig. 2 stage-1 chunks, Table I stage-2 transmissions, Table II
//! stage-3 needs, and the §III-C loads 1/4 + 1/4 + 1/2 = 1.
//!
//! Every id below is 0-based (paper is 1-based).

use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::master::Master;
use camr::net::Stage;
use camr::shuffle::plan::ChunkSpec;
use camr::workload::wordcount::WordCountWorkload;

fn master() -> Master {
    Master::new(SystemConfig::new(3, 2, 2).unwrap()).unwrap()
}

#[test]
fn eq2_ownership() {
    let m = master();
    assert_eq!(m.design.owners(0), &[0, 2, 4]); // X^(1) = {U1,U3,U5}
    assert_eq!(m.design.owners(1), &[0, 3, 5]); // X^(2) = {U1,U4,U6}
    assert_eq!(m.design.owners(2), &[1, 2, 5]); // X^(3) = {U2,U3,U6}
    assert_eq!(m.design.owners(3), &[1, 3, 4]); // X^(4) = {U2,U4,U5}
}

#[test]
fn fig1_placement() {
    // Fig. 1 (via Example 2): per-server stored batches. 4 batches of
    // γ = 2 subfiles each, μ = 1/3.
    let m = master();
    let inv = |s: usize| m.placement.inventory(s);
    // U1 stores J1:{B1,B2} and J2:{B1,B2} (its two owned jobs, minus the
    // self-labeled batch).
    assert_eq!(inv(0), vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
    assert_eq!(inv(1), vec![(2, 0), (2, 1), (3, 0), (3, 1)]); // U2
    assert_eq!(inv(2), vec![(0, 1), (0, 2), (2, 1), (2, 2)]); // U3
    assert_eq!(inv(3), vec![(1, 1), (1, 2), (3, 1), (3, 2)]); // U4
    assert_eq!(inv(4), vec![(0, 0), (0, 2), (3, 0), (3, 2)]); // U5
    assert_eq!(inv(5), vec![(1, 0), (1, 2), (2, 0), (2, 2)]); // U6
    // Dotted lines of Fig. 1: parallel classes {U1,U2}, {U3,U4}, {U5,U6}.
    assert_eq!(m.design.class_members(0), vec![0, 1]);
    assert_eq!(m.design.class_members(1), vec![2, 3]);
    assert_eq!(m.design.class_members(2), vec![4, 5]);
}

#[test]
fn example3_fig2_stage1_chunks() {
    // Example 3: among owners {U1,U3,U5} of J1, U1 needs the φ1
    // aggregate of batch {5,6}, U3 of {1,2}, U5 of {3,4}.
    let m = master();
    let schedule = m.schedule().unwrap();
    let g = &schedule.stage1[0];
    assert_eq!(g.members, vec![0, 2, 4]);
    assert_eq!(g.chunks[0], ChunkSpec { receiver: 0, job: 0, func: 0, batch: 2 });
    assert_eq!(g.chunks[1], ChunkSpec { receiver: 2, job: 0, func: 2, batch: 0 });
    assert_eq!(g.chunks[2], ChunkSpec { receiver: 4, job: 0, func: 4, batch: 1 });
    // Fig. 2: each broadcast is one packet of B/2 and there are k = 3 of
    // them per job → stage-1 total = J·k·B/2 = 6B.
}

#[test]
fn table1_stage2_group() {
    // Table I: the group {U1, U3, U6} recovers:
    //  U1 ← α(ν^{(3)}_{1,5}, ν^{(3)}_{1,6})   (job 3, batch {5,6})
    //  U3 ← α(ν^{(2)}_{3,1}, ν^{(2)}_{3,2})   (job 2, batch {1,2})
    //  U6 ← α(ν^{(1)}_{6,3}, ν^{(1)}_{6,4})   (job 1, batch {3,4})
    let m = master();
    let schedule = m.schedule().unwrap();
    let g = schedule
        .stage2
        .iter()
        .find(|g| g.members == vec![0, 2, 5])
        .expect("group {U1,U3,U6}");
    assert_eq!(g.chunks[0], ChunkSpec { receiver: 0, job: 2, func: 0, batch: 2 });
    assert_eq!(g.chunks[1], ChunkSpec { receiver: 2, job: 1, func: 2, batch: 0 });
    assert_eq!(g.chunks[2], ChunkSpec { receiver: 5, job: 0, func: 5, batch: 1 });
}

#[test]
fn stage2_has_q_pow_k1_qm1_groups() {
    // §III-C.2: q^{k-1}(q-1) = 4 groups for Example 1.
    let m = master();
    let schedule = m.schedule().unwrap();
    assert_eq!(schedule.stage2.len(), 4);
}

#[test]
fn table2_stage3_needs() {
    // Table II, all rows (0-based): receiver ← (job, fused subfiles).
    let m = master();
    let schedule = m.schedule().unwrap();
    let expect: Vec<(usize, usize, Vec<usize>)> = vec![
        (0, 2, vec![0, 1, 2, 3]),
        (0, 3, vec![0, 1, 2, 3]),
        (1, 0, vec![0, 1, 2, 3]),
        (1, 1, vec![0, 1, 2, 3]),
        (2, 1, vec![2, 3, 4, 5]),
        (2, 3, vec![2, 3, 4, 5]),
        (3, 0, vec![2, 3, 4, 5]),
        (3, 2, vec![2, 3, 4, 5]),
        (4, 1, vec![0, 1, 4, 5]),
        (4, 2, vec![0, 1, 4, 5]),
        (5, 0, vec![0, 1, 4, 5]),
        (5, 3, vec![0, 1, 4, 5]),
    ];
    assert_eq!(schedule.stage3.len(), expect.len());
    for (recv, job, subfiles) in expect {
        let u = schedule
            .stage3
            .iter()
            .find(|u| u.receiver == recv && u.job == job)
            .unwrap_or_else(|| panic!("missing unicast recv={recv} job={job}"));
        let got: Vec<usize> =
            u.batches.iter().flat_map(|&b| m.placement.batch_subfiles(b)).collect();
        assert_eq!(got, subfiles, "recv={recv} job={job}");
        // Example 5: the sender is the unique class-mate owner.
        assert_eq!(m.design.class_of(u.sender), m.design.class_of(recv));
    }
}

#[test]
fn example5_sender_is_u2_for_u1s_missing_jobs() {
    // Example 5: U1 still misses J3's values; they all reside at U2.
    let m = master();
    let schedule = m.schedule().unwrap();
    let u = schedule.stage3.iter().find(|u| u.receiver == 0 && u.job == 2).unwrap();
    assert_eq!(u.sender, 1);
}

#[test]
fn section3c_loads_measured_exactly() {
    // L1 = 1/4, L2 = 1/4, L3 = 1/2, total 1 — measured byte-exactly on
    // the Example-1 word count.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let wl = WordCountWorkload::example1(&cfg);
    let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);
    assert_eq!(e.bus.stage_bytes(Stage::Stage1), 6 * cfg.value_bytes); // 6B
    assert_eq!(e.bus.stage_bytes(Stage::Stage2), 6 * cfg.value_bytes); // 6B
    assert_eq!(e.bus.stage_bytes(Stage::Stage3), 12 * cfg.value_bytes); // 12B
    assert!((out.stage_load(1) - 0.25).abs() < 1e-15);
    assert!((out.stage_load(2) - 0.25).abs() < 1e-15);
    assert!((out.stage_load(3) - 0.5).abs() < 1e-15);
    assert!((out.total_load() - 1.0).abs() < 1e-15);
    // Transmission counts: stage 1 = J·k = 12 broadcasts, stage 2 =
    // 4 groups × 3, stage 3 = 12 unicasts.
    assert_eq!(e.bus.stage_count(Stage::Stage1), 12);
    assert_eq!(e.bus.stage_count(Stage::Stage2), 12);
    assert_eq!(e.bus.stage_count(Stage::Stage3), 12);
}
