//! End-to-end tests of the multi-job batch runtime
//! (`coordinator::batch`):
//!
//! - **Golden aggregation** — the aggregate ledger of an `N`-unit CAMR
//!   batch is byte-identical to `N` concatenations of the checked-in
//!   single-run golden ledger, on both engines, pooled and unpooled:
//!   batching changes *nothing* about what each job puts on the link.
//! - **Failure tolerance + pool hygiene** — injected per-unit map and
//!   verification failures are recorded, the rest of the batch
//!   completes, and the shared buffer pool comes back with
//!   `outstanding == 0` / `acquired == released`.
//! - **Closed forms** — executed job counts equal `analysis::jobs`'
//!   Table III formulas (`q^(k-1)` vs `C(K, μK+1)`).
//! - **Batch simulation** — pipelined ≤ barriered makespan, and the
//!   batch report is bit-deterministic across runs and engines.

use camr::analysis::jobs::JobRequirement;
use camr::config::{RunConfig, SystemConfig};
use camr::coordinator::batch::{
    run_batch, run_batch_synthetic, BatchOptions, BatchOutcome, BatchScheme,
};
use camr::error::CamrError;
use camr::net::Bus;
use camr::sim::SimConfig;
use camr::workload::synth::SyntheticWorkload;
use camr::workload::wordcount::WordCountWorkload;
use camr::workload::Workload;
use std::path::PathBuf;

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn example1_system() -> SystemConfig {
    RunConfig::from_path(&repo_path("configs/example1.toml"))
        .expect("configs/example1.toml parses")
        .system
}

/// Render a ledger in the golden fixture's line format (the job tag is
/// batch bookkeeping, deliberately not part of the per-run format).
fn render(bus: &Bus) -> String {
    let mut out = String::new();
    for t in bus.ledger() {
        let recipients: Vec<String> = t.recipients.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!("{} {} {} {}\n", t.stage, t.sender, t.bytes, recipients.join(",")));
    }
    out
}

/// The golden fixture's data lines (comments stripped).
fn fixture_contents() -> String {
    let text = std::fs::read_to_string(repo_path("rust/tests/golden/example1_ledger.txt"))
        .expect("golden fixture present");
    let mut out = String::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push_str(line);
        out.push('\n');
    }
    out
}

fn example1_batch(rounds: usize, parallel: bool, pooling: bool) -> BatchOutcome {
    let cfg = example1_system();
    let opts = BatchOptions {
        jobs: Some(rounds * cfg.jobs()),
        parallel,
        pooling,
        ..BatchOptions::default()
    };
    let cfg2 = cfg.clone();
    run_batch(&cfg, BatchScheme::Camr, &opts, &move |_, _| {
        Ok(Box::new(WordCountWorkload::example1(&cfg2)) as Box<dyn Workload>)
    })
    .expect("batch runs")
}

#[test]
fn aggregate_ledger_is_n_copies_of_the_golden_single_run_ledger() {
    let golden = fixture_contents();
    assert!(!golden.is_empty());
    for rounds in [1usize, 3] {
        let expect = golden.repeat(rounds);
        for parallel in [false, true] {
            for pooling in [true, false] {
                let out = example1_batch(rounds, parallel, pooling);
                assert!(out.all_verified());
                assert_eq!(out.units.len(), rounds);
                assert_eq!(out.bus.job_count(), rounds);
                assert_eq!(
                    render(&out.bus),
                    expect,
                    "rounds={rounds} parallel={parallel} pooling={pooling}: \
                     aggregate ledger is not {rounds}x the golden ledger"
                );
                // Job tags step 0..rounds in schedule order.
                let per_run = out.bus.ledger().len() / rounds;
                for (i, t) in out.bus.ledger().iter().enumerate() {
                    assert_eq!(t.job, i / per_run, "transmission {i} mis-tagged");
                }
            }
        }
    }
}

/// A workload whose map fails everywhere — models a unit whose input
/// data is gone.
struct FailingWorkload {
    inner: SyntheticWorkload,
}

impl Workload for FailingWorkload {
    fn name(&self) -> &str {
        "failing"
    }
    fn aggregator(&self) -> &dyn camr::agg::Aggregator {
        self.inner.aggregator()
    }
    fn map_subfile(&self, _job: usize, _subfile: usize) -> camr::error::Result<Vec<Vec<u8>>> {
        Err(CamrError::Runtime("injected unit failure".into()))
    }
}

/// A workload with one corrupted intermediate value — caught only by
/// oracle verification, i.e. by the batch's pipelined verifier.
struct CorruptingWorkload {
    inner: SyntheticWorkload,
}

impl Workload for CorruptingWorkload {
    fn name(&self) -> &str {
        "corrupting"
    }
    fn aggregator(&self) -> &dyn camr::agg::Aggregator {
        self.inner.aggregator()
    }
    fn map_subfile(&self, job: usize, subfile: usize) -> camr::error::Result<Vec<Vec<u8>>> {
        let mut vals = self.inner.map_subfile(job, subfile)?;
        if job == 0 && subfile == 1 {
            vals[0][0] ^= 0x01;
        }
        Ok(vals)
    }
    fn oracle(
        &self,
        cfg: &SystemConfig,
        job: usize,
        func: usize,
    ) -> camr::error::Result<Vec<u8>> {
        self.inner.oracle(cfg, job, func)
    }
}

fn batch_with_bad_unit(parallel: bool, corrupt_instead: bool) -> BatchOutcome {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let opts = BatchOptions {
        jobs: Some(4 * cfg.jobs()),
        parallel,
        strict: false,
        ..BatchOptions::default()
    };
    let cfg2 = cfg.clone();
    run_batch(&cfg, BatchScheme::Camr, &opts, &move |unit, seed| {
        let inner = SyntheticWorkload::new(&cfg2, seed);
        Ok(if unit != 1 {
            Box::new(inner) as Box<dyn Workload>
        } else if corrupt_instead {
            Box::new(CorruptingWorkload { inner })
        } else {
            Box::new(FailingWorkload { inner })
        })
    })
    .expect("non-strict batch completes")
}

#[test]
fn injected_unit_failures_are_recorded_and_pool_comes_back_clean() {
    for parallel in [false, true] {
        let out = batch_with_bad_unit(parallel, false);
        assert_eq!(out.units.len(), 4);
        assert!(!out.all_verified());
        let bad = &out.units[1];
        assert!(bad.error.as_deref().unwrap_or("").contains("injected unit failure"));
        assert_eq!(bad.bytes, 0, "failed unit contributes no link traffic");
        for u in [0usize, 2, 3] {
            assert!(out.units[u].verified, "parallel={parallel} unit {u}");
            assert!(out.units[u].bytes > 0);
        }
        // 3 of 4 units succeeded: 12 of 16 jobs, 3 ledger tags, 3 map
        // vectors — and the aggregate still simulates.
        assert_eq!(out.jobs_executed, 12);
        assert_eq!(out.jobs_attempted, 16);
        assert_eq!(out.bus.job_count(), 3);
        assert_eq!(out.maps.len(), 3);
        let sim = out.simulate(&SimConfig::commodity()).unwrap();
        assert!(sim.pipelined_secs > 0.0);
        // Pool hygiene across the failure: nothing leaked, nothing
        // double-released.
        let pool = out.pool.expect("CAMR batch reports pool stats");
        assert_eq!(pool.outstanding(), 0, "parallel={parallel}: {pool:?}");
        assert_eq!(pool.acquired, pool.released, "parallel={parallel}: {pool:?}");
        assert!(pool.acquired > 0);
    }
}

#[test]
fn corrupted_unit_is_caught_by_the_pipelined_verifier() {
    for parallel in [false, true] {
        let out = batch_with_bad_unit(parallel, true);
        assert!(!out.all_verified());
        let bad = &out.units[1];
        // The corruption executes fine (its traffic counts) but fails
        // oracle verification on the background thread.
        assert!(bad.bytes > 0);
        assert!(!bad.verified);
        assert!(bad.error.as_deref().unwrap_or("").contains("mismatch"), "{:?}", bad.error);
        // Its traffic was appended before verification vetoed the unit:
        // all four tags are present; maps align.
        assert_eq!(out.bus.job_count(), 4);
        assert_eq!(out.maps.len(), 4);
        assert_eq!(out.jobs_executed, 12, "vetoed unit's jobs don't count as executed");
        let pool = out.pool.unwrap();
        assert_eq!(pool.outstanding(), 0);
    }
}

#[test]
fn strict_batches_surface_the_first_unit_error() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let opts =
        BatchOptions { jobs: Some(2 * cfg.jobs()), strict: true, ..BatchOptions::default() };
    let cfg2 = cfg.clone();
    let err = run_batch(&cfg, BatchScheme::Camr, &opts, &move |unit, seed| {
        let inner = SyntheticWorkload::new(&cfg2, seed);
        Ok(if unit == 1 {
            Box::new(FailingWorkload { inner }) as Box<dyn Workload>
        } else {
            Box::new(inner)
        })
    })
    .expect_err("strict batch must fail");
    assert!(err.to_string().contains("injected unit failure"), "got: {err}");
}

#[test]
fn executed_job_counts_match_table3_closed_forms() {
    for (k, q) in [(3usize, 2usize), (2, 3)] {
        let cfg = SystemConfig::new(k, q, 1).unwrap();
        let req = JobRequirement::for_params(k, q);
        let camr = run_batch_synthetic(&cfg, BatchScheme::Camr, &BatchOptions::default())
            .unwrap();
        assert_eq!(camr.jobs_executed as u128, req.camr, "k={k} q={q}");
        assert_eq!(camr.jobs_required, req.camr);
        let ccdc = run_batch_synthetic(&cfg, BatchScheme::Ccdc, &BatchOptions::default())
            .unwrap();
        assert_eq!(ccdc.jobs_required, req.ccdc, "k={k} q={q}");
        assert_eq!(ccdc.jobs_executed as u128, req.ccdc.min(1000), "cap covers these");
        assert!(camr.jobs_required < ccdc.jobs_required);
        let unc = run_batch_synthetic(&cfg, BatchScheme::Uncoded, &BatchOptions::default())
            .unwrap();
        assert_eq!(unc.jobs_executed as u128, req.camr, "same placement, same job set");
    }
}

#[test]
fn batch_simulation_is_deterministic_and_pipelining_never_hurts() {
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    let opts = BatchOptions { jobs: Some(3 * cfg.jobs()), ..BatchOptions::default() };
    let serial = run_batch_synthetic(&cfg, BatchScheme::Camr, &opts).unwrap();
    let par = run_batch_synthetic(
        &cfg,
        BatchScheme::Camr,
        &BatchOptions { parallel: true, ..opts.clone() },
    )
    .unwrap();
    let mut sc = SimConfig::commodity();
    sc.link_bytes_per_sec = 2e5;
    let a = serial.simulate(&sc).unwrap();
    assert!(a.pipelined_secs <= a.serial_secs + 1e-12);
    assert!(a.pipelined_secs + 1e-12 >= a.shuffle_secs_total);
    // Ten replays and the other engine's ledger: bit-identical reports.
    let reference = a.to_json().render();
    for i in 0..10 {
        assert_eq!(serial.simulate(&sc).unwrap().to_json().render(), reference, "run {i}");
    }
    assert_eq!(
        par.simulate(&sc).unwrap().to_json().render(),
        reference,
        "parallel-engine aggregate ledger simulated differently"
    );
}
