//! Ledger equality across every data plane: the socket transport must
//! be indistinguishable from the serial engine *to the byte*.
//!
//! The checked-in golden fixture `rust/tests/golden/example1_ledger.txt`
//! pins the serial schedule's shared-link ledger for
//! `configs/example1.toml` (paper Example 1). This suite runs the same
//! config over all four planes — serial, in-process channels, loopback
//! TCP and a Unix-domain socket (the socket planes both with worker
//! threads and with real `camr worker --connect` subprocesses) — and
//! asserts every ledger is byte-identical to that fixture, including
//! transmission *order*. The ledger records only sizes and routing, so
//! the fixture (captured from `WordCountWorkload::example1`) also pins
//! the deterministic `build_native` word-count workload the socket
//! workers rebuild from the shipped config text: same shape, same
//! schedule, same bytes.

use camr::config::RunConfig;
use camr::coordinator::engine::{Engine, RunOutcome};
use camr::coordinator::parallel::{ParallelEngine, TransportKind};
use camr::coordinator::remote::{SocketOptions, WorkerSpec};
use camr::net::Bus;
use camr::workload::build_native;
use std::path::PathBuf;

fn example1_config() -> RunConfig {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("configs/example1.toml");
    RunConfig::from_path(&path).expect("configs/example1.toml parses")
}

/// Render a ledger in the fixture's line format:
/// `<stage> <sender> <bytes> <recipient,...>`.
fn render(bus: &Bus) -> String {
    let mut out = String::new();
    for t in bus.ledger() {
        let recipients: Vec<String> = t.recipients.iter().map(|r| r.to_string()).collect();
        out.push_str(&format!(
            "{} {} {} {}\n",
            t.stage,
            t.sender,
            t.bytes,
            recipients.join(",")
        ));
    }
    out
}

/// The fixture's data lines (comments stripped), newline-terminated.
fn fixture_contents() -> String {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/example1_ledger.txt");
    let text = std::fs::read_to_string(path).expect("golden fixture exists");
    let mut out = String::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push_str(line);
        out.push('\n');
    }
    out
}

/// Serial reference run on the deterministic `build_native` workload —
/// the same workload socket workers reconstruct from the shipped config.
fn run_serial() -> (Engine, RunOutcome) {
    let rc = example1_config();
    let wl = build_native(rc.workload, &rc.system, rc.seed).unwrap();
    let mut e = Engine::new(rc.system, wl).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified, "serial reference failed verification");
    (e, out)
}

/// One run over the given transport plane. `build_native` on both sides:
/// in-process for the hub's verification oracle, and (for socket planes)
/// rebuilt by each worker from the shipped `remote_spec`.
fn run_over(transport: TransportKind) -> (ParallelEngine, RunOutcome) {
    let rc = example1_config();
    let wl = build_native(rc.workload, &rc.system, rc.seed).unwrap();
    let mut e = ParallelEngine::new(rc.system, wl).unwrap();
    e.remote_spec = Some(WorkerSpec {
        kind: rc.workload,
        seed: rc.seed,
    });
    e.transport = transport;
    let out = e.run().unwrap();
    assert!(out.verified, "run failed verification");
    (e, out)
}

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_camr"))
}

/// The four-plane equality matrix, against the fixture and each other.
#[test]
fn ledgers_byte_identical_across_all_four_planes() {
    let fixture = fixture_contents();
    assert!(!fixture.is_empty(), "fixture has data lines");

    let (serial, sout) = run_serial();
    assert_eq!(render(&serial.bus), fixture, "serial ledger != fixture");

    let (chan, cout) = run_over(TransportKind::Chan);
    assert_eq!(render(&chan.bus), fixture, "channel-plane ledger != fixture");

    let (tcp, tout) = run_over(TransportKind::Socket(SocketOptions::tcp_threads()));
    assert_eq!(render(&tcp.bus), fixture, "TCP ledger != fixture");

    let (unix, uout) = run_over(TransportKind::Socket(SocketOptions::unix_threads()));
    assert_eq!(render(&unix.bus), fixture, "Unix-socket ledger != fixture");

    // Same measured loads everywhere, pinned to the paper's closed form
    // for Example 1: stage bytes [6B, 6B, 12B] with B = value_bytes.
    let b = example1_config().system.value_bytes;
    for (label, out) in [
        ("serial", &sout),
        ("chan", &cout),
        ("tcp", &tout),
        ("unix", &uout),
    ] {
        assert_eq!(out.stage_bytes, [6 * b, 6 * b, 12 * b], "{label} stage bytes");
        assert!(out.verified, "{label} unverified");
    }
}

/// Reduced outputs (not just their byte counts) agree between the serial
/// engine and a socket plane that shipped them back over the wire.
#[test]
fn socket_outputs_match_serial_outputs_value_for_value() {
    let (serial, sout) = run_serial();
    let (unix, uout) = run_over(TransportKind::Socket(SocketOptions::unix_threads()));
    assert_eq!(sout.outputs, uout.outputs, "output counts differ");
    let cfg = example1_config().system;
    for j in 0..cfg.jobs() {
        for f in 0..cfg.functions() {
            assert_eq!(
                serial.output(j, f),
                unix.output(j, f),
                "job {j} func {f} diverged over the socket plane"
            );
        }
    }
    assert_eq!(sout.map_invocations, uout.map_invocations);
}

/// Real subprocess workers (`camr worker --connect`) over both socket
/// families still reproduce the fixture byte for byte.
#[test]
fn worker_subprocesses_reproduce_the_golden_ledger() {
    let fixture = fixture_contents();
    let (tcp, tout) = run_over(TransportKind::Socket(SocketOptions::tcp_processes(worker_exe())));
    assert_eq!(render(&tcp.bus), fixture, "TCP subprocess ledger != fixture");
    let (unix, uout) =
        run_over(TransportKind::Socket(SocketOptions::unix_processes(worker_exe())));
    assert_eq!(render(&unix.bus), fixture, "Unix subprocess ledger != fixture");
    let b = example1_config().system.value_bytes;
    assert_eq!(tout.stage_bytes, [6 * b, 6 * b, 12 * b]);
    assert_eq!(uout.stage_bytes, [6 * b, 6 * b, 12 * b]);
    // Subprocess workers really mapped: the Done frame carried the count.
    assert!(tout.map_invocations > 0);
    assert_eq!(tout.map_invocations, uout.map_invocations);
}

/// Socket runs are deterministic: ten consecutive runs over a socket
/// plane produce the identical ledger despite scheduler and accept-order
/// nondeterminism (the sequence-number sort restores canonical order).
#[test]
fn ten_socket_runs_are_ledger_deterministic() {
    let reference = fixture_contents();
    for i in 0..10 {
        let (e, out) = run_over(TransportKind::Socket(SocketOptions::unix_threads()));
        assert_eq!(render(&e.bus), reference, "run {i} ledger drifted");
        assert!(out.verified);
    }
}

/// The pooled data plane stays clean over sockets: every hub-side buffer
/// acquired during the run is back in the pool when the run returns.
#[test]
fn socket_run_leaves_buffer_pool_clean() {
    let (e, _) = run_over(TransportKind::Socket(SocketOptions::unix_threads()));
    let stats = e.pool_stats();
    assert_eq!(stats.outstanding(), 0, "pooled buffers leaked: {stats:?}");
    assert_eq!(stats.acquired, stats.released);
}
