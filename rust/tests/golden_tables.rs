//! Golden reproduction tests: the paper's Table III minimum job counts
//! and the per-stage loads of Example 1 (`K = 6, q = 2, k = 3, J = 4`),
//! measured on both execution engines.

use camr::analysis::jobs::{binomial, table3, JobRequirement};
use camr::analysis::load;
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::workload::wordcount::WordCountWorkload;

#[test]
fn golden_table3_minimum_job_counts() {
    // Table III, K = 100: (k, J_CAMR, J_CCDC).
    let golden: [(usize, u128, u128); 3] =
        [(2, 50, 4_950), (4, 15_625, 3_921_225), (5, 160_000, 75_287_520)];
    let rows = table3();
    assert_eq!(rows.len(), golden.len());
    for (row, (k, camr, ccdc)) in rows.iter().zip(golden) {
        assert_eq!(row.k, k);
        assert_eq!(row.servers, 100);
        assert_eq!(row.camr, camr, "k={k}: J_CAMR");
        assert_eq!(row.ccdc, ccdc, "k={k}: J_CCDC");
        assert!(row.ratio() > 1.0);
    }
    // The §III-C running example: CCDC needs C(6,3) = 20 jobs, CAMR 4.
    assert_eq!(binomial(6, 3), 20);
    let r = JobRequirement::for_params(3, 2);
    assert_eq!((r.camr, r.ccdc), (4, 20));
}

#[test]
fn golden_example1_parameters_and_per_stage_loads() {
    // K = 6, q = 2, k = 3 → J = 4 jobs, N = 6 subfiles, μ = 1/3.
    let cfg = SystemConfig::new(3, 2, 2).unwrap();
    assert_eq!(cfg.servers(), 6);
    assert_eq!(cfg.jobs(), 4);
    assert_eq!(cfg.subfiles(), 6);
    assert!((cfg.storage_fraction() - 1.0 / 3.0).abs() < 1e-12);

    // Closed forms: L1 = 1/4, L2 = 1/4, L3 = 1/2.
    let forms = load::camr_stages(3, 2);
    assert!((forms.stage1 - 0.25).abs() < 1e-12);
    assert!((forms.stage2 - 0.25).abs() < 1e-12);
    assert!((forms.stage3 - 0.50).abs() < 1e-12);

    // Measured byte-exactly on both engines with the Example-1 workload.
    let golden_stage_loads = [0.25, 0.25, 0.50];
    let souts = {
        let wl = WordCountWorkload::example1(&cfg);
        let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.run().unwrap()
    };
    let pouts = {
        let wl = WordCountWorkload::example1(&cfg);
        let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
        e.run().unwrap()
    };
    for out in [&souts, &pouts] {
        assert!(out.verified);
        for (i, want) in golden_stage_loads.iter().enumerate() {
            assert!(
                (out.stage_load(i + 1) - want).abs() < 1e-15,
                "stage {}: {} != {want}",
                i + 1,
                out.stage_load(i + 1)
            );
        }
        assert!((out.total_load() - 1.0).abs() < 1e-15);
        // Computation load: each subfile mapped by k-1 = 2 servers.
        assert_eq!(out.map_invocations, 2 * 4 * 6);
    }
    assert_eq!(souts.stage_bytes, pouts.stage_bytes);
}

#[test]
fn golden_loads_across_table_parameters() {
    // Spot-check the §IV closed form at Table-III-style parameters
    // without instantiating K = 100 clusters.
    for (k, q, expect) in [
        (2usize, 50usize, (2.0 * 49.0 + 1.0) / 50.0),
        (4, 25, (4.0 * 24.0 + 1.0) / (25.0 * 3.0)),
        (5, 20, (5.0 * 19.0 + 1.0) / (20.0 * 4.0)),
    ] {
        assert!((load::camr_total(k, q) - expect).abs() < 1e-12, "k={k} q={q}");
        // §V: CCDC at matched μ gives the identical load.
        assert!((load::ccdc_total(k - 1, k * q) - expect).abs() < 1e-12);
    }
}
