//! Integration suite for the static verification layer's plan prover
//! (`camr::check`): every (q, k) grid point proves clean, every seeded
//! plan mutation is rejected with its specific diagnostic code, every
//! shipped config proves clean, and the prover agrees with the
//! executed oracle verification on `configs/example1.toml`.
//!
//! The mutation tests edit [`PlanFacts`] — the prover's explicit fact
//! base — rather than the constructors, so each defect is exactly the
//! one seeded: a dropped delivery-group member, skewed replication, a
//! duplicated schedule sequence number, a dropped group, a corrupted
//! reducer assignment, a retargeted chunk.

use camr::check::{prove, PlanFacts};
use camr::config::{RunConfig, SystemConfig};
use camr::coordinator::engine::Engine;
use camr::service::{JobService, ServiceOptions};
use camr::util::json::Json;
use camr::workload::wordcount::WordCountWorkload;
use std::path::PathBuf;

/// The (k, q) grid every prover property is exercised over. Covers the
/// smallest legal system, asymmetric shapes in both directions, and a
/// k = q case.
const GRID: [(usize, usize); 5] = [(2, 2), (2, 3), (3, 2), (3, 3), (4, 2)];

fn facts(k: usize, q: usize) -> PlanFacts {
    let cfg = SystemConfig::new(k, q, 1).unwrap();
    PlanFacts::from_config(&cfg).unwrap()
}

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

#[test]
fn unmutated_grid_proves_clean() {
    for (k, q) in GRID {
        let f = facts(k, q);
        let report = prove(&f);
        assert!(
            report.diagnostics.is_empty(),
            "k={k} q={q}: {:?}",
            report.diagnostics
        );
    }
}

#[test]
fn dropped_group_member_rejected_with_p104() {
    for (k, q) in GRID {
        let mut f = facts(k, q);
        f.stage1[0].group.members.pop();
        let report = prove(&f);
        assert!(!report.is_clean(), "k={k} q={q}");
        assert!(report.has_code("P104"), "k={k} q={q}: {:?}", report.diagnostics);
    }
}

#[test]
fn skewed_replication_rejected_with_p103() {
    for (k, q) in GRID {
        // Under-replication: delete one stored (server, job, batch).
        let mut f = facts(k, q);
        let victim = *f.stored.iter().next().unwrap();
        f.stored.remove(&victim);
        let report = prove(&f);
        assert!(report.has_code("P103"), "k={k} q={q}: {:?}", report.diagnostics);
        // The same hole breaks decodability of some coded packet.
        assert!(report.has_code("P105"), "k={k} q={q}: {:?}", report.diagnostics);

        // Over-replication: a server maps a batch labeled for itself.
        let mut f = facts(k, q);
        let (j, own) = (0, f.owners[0].clone());
        let extra = own
            .iter()
            .copied()
            .find_map(|s| (0..f.k).find(|&b| !f.stored.contains(&(s, j, b))).map(|b| (s, j, b)))
            .expect("every owner skips exactly one batch");
        f.stored.insert(extra);
        let report = prove(&f);
        assert!(report.has_code("P103"), "k={k} q={q}: {:?}", report.diagnostics);
    }
}

#[test]
fn duplicated_sequence_rejected_with_p108() {
    for (k, q) in GRID {
        let mut f = facts(k, q);
        // Stage 3 always has >= 2 unicasts on this grid.
        f.stage3[1].seq = f.stage3[0].seq;
        let report = prove(&f);
        assert!(report.has_code("P108"), "k={k} q={q}: {:?}", report.diagnostics);
    }
}

#[test]
fn dropped_group_breaks_coverage_and_partition() {
    for (k, q) in GRID {
        let mut f = facts(k, q);
        f.stage1.pop();
        // Re-stamp so the defect is the missing group, not its seq.
        for (i, g) in f.stage1.iter_mut().enumerate() {
            g.seq = i;
        }
        let report = prove(&f);
        assert!(report.has_code("P107"), "k={k} q={q}: {:?}", report.diagnostics);
        assert!(report.has_code("P109"), "k={k} q={q}: {:?}", report.diagnostics);
    }
}

#[test]
fn corrupted_reducer_assignment_rejected_with_p106() {
    for (k, q) in GRID {
        let mut f = facts(k, q);
        // Point the chunk's function at a different server's slice.
        let c = &mut f.stage1[0].group.chunks[0];
        c.func += 1;
        let report = prove(&f);
        assert!(report.has_code("P106"), "k={k} q={q}: {:?}", report.diagnostics);
    }
}

#[test]
fn retargeted_chunk_rejected_with_p104() {
    for (k, q) in GRID {
        let mut f = facts(k, q);
        // Address member 0's chunk to member 1 instead.
        let other = f.stage1[0].group.members[1];
        f.stage1[0].group.chunks[0].receiver = other;
        let report = prove(&f);
        assert!(report.has_code("P104"), "k={k} q={q}: {:?}", report.diagnostics);
    }
}

#[test]
fn every_shipped_config_proves_clean() {
    for name in ["example1", "matvec_pjrt", "serve", "straggler"] {
        let rc = RunConfig::from_path(&repo_path(&format!("configs/{name}.toml")))
            .unwrap_or_else(|e| panic!("configs/{name}.toml: {e}"));
        let f = PlanFacts::from_config(&rc.system).unwrap();
        let report = prove(&f);
        assert!(
            report.diagnostics.is_empty(),
            "configs/{name}.toml: {:?}",
            report.diagnostics
        );
    }
}

#[test]
fn prover_agrees_with_executed_oracle_on_example1() {
    let rc = RunConfig::from_path(&repo_path("configs/example1.toml")).unwrap();
    // Static side: the plan proves clean.
    let f = PlanFacts::from_config(&rc.system).unwrap();
    assert!(prove(&f).is_clean());
    // Dynamic side: the same plan executes and oracle-verifies. The
    // prover guarantees plan correctness, execution shows data
    // correctness; on a shipped config both must hold.
    let wl = WordCountWorkload::example1(&rc.system);
    let mut e = Engine::new(rc.system, Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified, "oracle verification failed on a proven plan");
}

#[test]
fn json_export_round_trips_for_a_real_report() {
    let mut f = facts(3, 2);
    f.stage2[0].group.members.pop();
    let report = prove(&f);
    let j = report.to_json();
    assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
    let back = Json::parse(&j.render()).unwrap();
    assert_eq!(back, j);
}

#[test]
fn engine_preflight_accepts_all_grid_configs() {
    for (k, q) in GRID {
        let cfg = SystemConfig::with_options(k, q, 1, 1, 16).unwrap();
        let wl = camr::workload::synth::SyntheticWorkload::new(&cfg, 7);
        // Engine::new now runs the prover; a valid config must pass.
        let mut e = Engine::new(cfg, Box::new(wl)).unwrap();
        assert!(e.run().unwrap().verified);
    }
}

#[test]
fn service_admission_preflight_accepts_valid_config() {
    let cfg = SystemConfig::with_options(2, 2, 1, 1, 16).unwrap();
    let svc = JobService::start(
        cfg,
        ServiceOptions { engines: 1, ..ServiceOptions::default() },
    )
    .unwrap();
    svc.drain().unwrap();
}
