//! End-to-end tests of the discrete-event cluster simulator:
//!
//! - **Degenerate-case identity** — with zero latency, homogeneous
//!   workers, and no stragglers, the simulator must reproduce the
//!   closed-form `TimeModel::phase_times` *bit-exactly*, on the ledgers
//!   of both engines, so the two models can never silently diverge.
//! - **Determinism** — same seed + same config ⇒ byte-identical JSON
//!   across 10 runs, on both serial and parallel engine ledgers;
//!   different straggler seeds perturb times but never ledger bytes.
//! - **Golden-fixture replay** — the checked-in PR 2 ledger fixture
//!   simulates identically to a live run.
//! - **Pinned straggler scenario** — `configs/straggler.toml` (fixed
//!   seed, shifted-exponential stragglers, slow link): simulated CAMR
//!   completion time beats the uncoded baseline.

use camr::baseline::{UncodedEngine, UncodedMode};
use camr::config::{RunConfig, SystemConfig};
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::net::{Bus, Stage, Transmission};
use camr::sim::{self, SimConfig, StragglerModel, TimeModel};
use camr::workload::synth::SyntheticWorkload;
use std::path::PathBuf;

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// Run the serial engine; return (per-worker maps, ledger, outcome).
fn run_serial(cfg: &SystemConfig, seed: u64) -> (Vec<usize>, Bus, usize) {
    let wl = SyntheticWorkload::new(cfg, seed);
    let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);
    let maps = sim::camr_per_worker_maps(cfg, &e.master.placement);
    (maps, e.bus.clone(), out.map_invocations)
}

fn run_parallel(cfg: &SystemConfig, seed: u64) -> (Vec<usize>, Bus) {
    let wl = SyntheticWorkload::new(cfg, seed);
    let mut e = ParallelEngine::new(cfg.clone(), Box::new(wl)).unwrap();
    let out = e.run().unwrap();
    assert!(out.verified);
    let maps = sim::camr_per_worker_maps(cfg, &e.master.placement);
    (maps, e.bus.clone())
}

#[test]
fn degenerate_case_equals_closed_form_bit_exactly() {
    // Zero latency + homogeneous + no stragglers + shared link must
    // reproduce TimeModel::phase_times with *f64 equality* — on the
    // ledgers of both engines, across several (k, q, γ).
    for (k, q, gamma) in [(3, 2, 2), (3, 3, 1), (4, 2, 2), (2, 3, 1)] {
        let cfg = SystemConfig::new(k, q, gamma).unwrap();
        let (maps, bus, invocations) = run_serial(&cfg, 7);
        assert_eq!(maps.iter().sum::<usize>(), invocations, "map accounting drifted");
        let sc = SimConfig::commodity();
        assert_eq!(sc.latency_secs, 0.0);
        assert!(sc.speeds.is_empty() && sc.straggler == StragglerModel::Deterministic);
        let tm = sc.time_model();
        let bytes: usize = bus.ledger().iter().map(|t| t.bytes).sum();
        let (m, s) = tm.phase_times(cfg.servers(), invocations, bytes as f64);

        let out = sim::simulate(&sc, &maps, bus.ledger()).unwrap();
        assert_eq!(out.map_secs, m, "k={k} q={q}: map time != closed form");
        assert_eq!(out.shuffle_secs, s, "k={k} q={q}: shuffle time != closed form");
        assert_eq!(out.total_secs, tm.job_time(cfg.servers(), invocations, bytes as f64));

        // The parallel engine's ledger is byte-identical, so its
        // simulated times must be too.
        let (pmaps, pbus) = run_parallel(&cfg, 7);
        let pout = sim::simulate(&sc, &pmaps, pbus.ledger()).unwrap();
        assert_eq!(pout.total_secs, out.total_secs, "k={k} q={q}: engines diverged");
    }
}

#[test]
fn degenerate_case_holds_for_config_file_sim_section() {
    // configs/example1.toml pins the commodity preset in TOML; parsing
    // it must land exactly on TimeModel::commodity.
    let rc = RunConfig::from_path(&repo_path("configs/example1.toml")).unwrap();
    let sc = rc.sim.expect("example1.toml has a [sim] section");
    let tm = TimeModel::commodity();
    assert_eq!(sc.link_bytes_per_sec, tm.link_bytes_per_sec);
    assert_eq!(sc.secs_per_map, tm.secs_per_map);
    assert_eq!(sc.latency_secs, 0.0);

    let (maps, bus, invocations) = run_serial(&rc.system, rc.seed);
    let out = sim::simulate(&sc, &maps, bus.ledger()).unwrap();
    let bytes: usize = bus.ledger().iter().map(|t| t.bytes).sum();
    let (m, s) = tm.phase_times(rc.system.servers(), invocations, bytes as f64);
    assert_eq!(out.map_secs, m);
    assert_eq!(out.shuffle_secs, s);
    // Example 1 at 1 Gb/s, 1 ms maps: 8 maps/worker + 1536 B shuffle.
    assert_eq!(out.map_secs, 0.008);
    assert_eq!(out.shuffle_bytes, 1536);
}

#[test]
fn same_seed_is_byte_identical_across_ten_runs_and_both_engines() {
    let rc = RunConfig::from_path(&repo_path("configs/straggler.toml")).unwrap();
    let sc = rc.sim.clone().expect("straggler.toml has a [sim] section");
    let (maps, bus, _) = run_serial(&rc.system, rc.seed);

    let reference = sim::simulate(&sc, &maps, bus.ledger()).unwrap().to_json().render();
    for i in 0..10 {
        let again = sim::simulate(&sc, &maps, bus.ledger()).unwrap().to_json().render();
        assert_eq!(again, reference, "run {i} diverged");
    }
    // The parallel engine's ledger is byte-identical (PR 1 invariant),
    // so the simulated report must be too.
    let (pmaps, pbus) = run_parallel(&rc.system, rc.seed);
    let par = sim::simulate(&sc, &pmaps, pbus.ledger()).unwrap().to_json().render();
    assert_eq!(par, reference, "parallel-engine ledger simulated differently");
}

#[test]
fn different_straggler_seeds_perturb_times_but_never_ledger_bytes() {
    let rc = RunConfig::from_path(&repo_path("configs/straggler.toml")).unwrap();
    let mut sc = rc.sim.clone().unwrap();
    let (maps, bus, _) = run_serial(&rc.system, rc.seed);
    let ledger_before: Vec<(Stage, usize, usize)> =
        bus.ledger().iter().map(|t| (t.stage, t.sender, t.bytes)).collect();

    let a = sim::simulate(&sc, &maps, bus.ledger()).unwrap();
    sc.seed = sc.seed.wrapping_add(1);
    let b = sim::simulate(&sc, &maps, bus.ledger()).unwrap();
    assert_ne!(a.total_secs, b.total_secs, "straggler seed must perturb times");
    assert_eq!(a.shuffle_bytes, b.shuffle_bytes, "bytes are an input, never perturbed");

    // The ledger object itself is untouched, and a fresh engine run
    // still produces the same bytes regardless of any sim seed.
    let after: Vec<(Stage, usize, usize)> =
        bus.ledger().iter().map(|t| (t.stage, t.sender, t.bytes)).collect();
    assert_eq!(after, ledger_before);
    let (_, bus2, _) = run_serial(&rc.system, rc.seed);
    let again: Vec<(Stage, usize, usize)> =
        bus2.ledger().iter().map(|t| (t.stage, t.sender, t.bytes)).collect();
    assert_eq!(again, ledger_before);
}

/// Parse the PR 2 golden fixture into a replayable ledger.
fn fixture_ledger() -> Vec<Transmission> {
    let text = std::fs::read_to_string(repo_path("rust/tests/golden/example1_ledger.txt"))
        .expect("golden fixture present");
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let stage = Stage::parse(parts.next().unwrap()).expect("valid stage tag");
        let sender: usize = parts.next().unwrap().parse().unwrap();
        let bytes: usize = parts.next().unwrap().parse().unwrap();
        let recipients: Vec<usize> = parts
            .next()
            .map(|r| r.split(',').map(|x| x.parse().unwrap()).collect())
            .unwrap_or_default();
        out.push(Transmission { stage, sender, recipients, bytes, job: 0 });
    }
    out
}

#[test]
fn golden_fixture_replays_identically_to_a_live_run() {
    // The simulator consumes recorded ledgers: feeding it the
    // checked-in PR 2 fixture must give byte-identical output to
    // feeding it a live serial run of the same config.
    let rc = RunConfig::from_path(&repo_path("configs/example1.toml")).unwrap();
    let sc = rc.sim.unwrap();
    let (maps, bus, _) = run_serial(&rc.system, rc.seed);
    let fixture = fixture_ledger();
    assert_eq!(fixture.len(), bus.ledger().len(), "fixture/live ledger length mismatch");
    let live = sim::simulate(&sc, &maps, bus.ledger()).unwrap().to_json().render();
    let replay = sim::simulate(&sc, &maps, &fixture).unwrap().to_json().render();
    assert_eq!(replay, live);
}

#[test]
fn pinned_straggler_scenario_camr_beats_uncoded() {
    // configs/straggler.toml: shifted-exponential stragglers (seed 42),
    // 10 MB/s shared link, heterogeneous speeds. CAMR and the
    // uncoded-aggregated baseline run the *identical* map phase (same
    // placement, same per-worker task counts, same addressable
    // straggler draws), so the completion-time gap is purely the coded
    // shuffle.
    let rc = RunConfig::from_path(&repo_path("configs/straggler.toml")).unwrap();
    let sc = rc.sim.clone().unwrap();
    assert_eq!(sc.seed, 42, "scenario seed is pinned");
    assert_eq!(sc.straggler, StragglerModel::ShiftedExp { rate: 5.0 });

    let (maps, camr_bus, _) = run_serial(&rc.system, rc.seed);
    let wl = SyntheticWorkload::new(&rc.system, rc.seed);
    let mut ue = UncodedEngine::new(rc.system.clone(), Box::new(wl), UncodedMode::Aggregated)
        .unwrap();
    let uout = ue.run().unwrap();
    assert!(uout.verified);

    let camr = sim::simulate(&sc, &maps, camr_bus.ledger()).unwrap();
    let unc = sim::simulate(&sc, &maps, ue.bus.ledger()).unwrap();

    // Identical map phases, bit-exactly.
    assert_eq!(camr.map_secs.to_bits(), unc.map_secs.to_bits());
    // Stragglers really stretched the map barrier beyond nominal
    // (8 tasks × 1 ms / slowest speed 0.8 = 10 ms nominal).
    assert!(camr.map_secs > 0.010, "map barrier = {}", camr.map_secs);
    // Coded shuffle moves fewer bytes (paper: L=1 vs 2-k/K=1.5) …
    assert_eq!(camr.shuffle_bytes, 1536);
    assert_eq!(unc.shuffle_bytes, 2304);
    // … and therefore finishes sooner, end to end.
    assert!(camr.shuffle_secs < unc.shuffle_secs);
    assert!(
        camr.total_secs < unc.total_secs,
        "CAMR {} !< uncoded {}",
        camr.total_secs,
        unc.total_secs
    );
}

#[test]
fn bisection_link_is_never_slower_than_shared() {
    let rc = RunConfig::from_path(&repo_path("configs/example1.toml")).unwrap();
    let mut sc = rc.sim.unwrap();
    let (maps, bus, _) = run_serial(&rc.system, rc.seed);
    let shared = sim::simulate(&sc, &maps, bus.ledger()).unwrap();
    sc.link = camr::sim::LinkKind::Bisection;
    let bis = sim::simulate(&sc, &maps, bus.ledger()).unwrap();
    assert!(bis.shuffle_secs <= shared.shuffle_secs);
    // CAMR's shuffle has many distinct senders per stage, so the
    // bisection fabric strictly overlaps them.
    assert!(bis.shuffle_secs < shared.shuffle_secs);
    assert_eq!(bis.shuffle_bytes, shared.shuffle_bytes);
}
