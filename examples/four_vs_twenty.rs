//! The paper's §III-C job-count comparison, *executed*: at `K = 6`
//! servers and storage fraction `μ = 1/3` (k = 3, q = 2), CAMR needs
//! `J = q^(k-1) = 4` jobs while CCDC needs `C(6, 3) = 20` — the same
//! communication load, five times the workload floor.
//!
//! Earlier PRs only *counted* those jobs (`analysis::jobs`, Table III);
//! this example runs both full job sets end to end through the batch
//! runtime — every map invocation, coded packet and reduce output real
//! and oracle-verified — then replays the aggregate job-tagged ledgers
//! through the cluster simulator for completion times, and cross-checks
//! the executed counts against the closed forms.
//!
//! Run: `cargo run --release --example four_vs_twenty [-- --quick]`

use camr::analysis::jobs::JobRequirement;
use camr::config::SystemConfig;
use camr::coordinator::batch::{run_batch_synthetic, BatchOptions, BatchScheme};
use camr::report::Table;
use camr::sim::SimConfig;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = SystemConfig::new(3, 2, 2)?;
    let req = JobRequirement::for_params(cfg.k, cfg.q);
    println!(
        "== §III-C executed: K={} μ=1/3 — CAMR's {} jobs vs CCDC's C({},{}) = {} ==\n",
        cfg.servers(),
        req.camr,
        cfg.servers(),
        cfg.k,
        req.ccdc
    );

    // A slow shared link so shuffle time dominates and the batch
    // pipeline has map work to hide.
    let mut sc = SimConfig::commodity();
    sc.link_bytes_per_sec = 1e5;

    let mut t = Table::new(vec![
        "scheme", "required", "executed", "units", "bytes", "wall_ms", "sim_pipelined_s",
        "s/job",
    ]);
    let mut per_job: Vec<(BatchScheme, f64)> = Vec::new();
    for scheme in [BatchScheme::Camr, BatchScheme::Ccdc, BatchScheme::Uncoded] {
        let out = run_batch_synthetic(&cfg, scheme, &BatchOptions::default())?;
        anyhow::ensure!(out.all_verified(), "{} batch failed", scheme.label());
        let sim = out.simulate(&sc)?;
        let spj = sim.pipelined_secs / out.jobs_executed as f64;
        per_job.push((scheme, spj));
        t.row(vec![
            scheme.label().to_string(),
            out.jobs_required.to_string(),
            out.jobs_executed.to_string(),
            out.units.len().to_string(),
            out.total_bytes().to_string(),
            format!("{:.3}", out.wall.as_secs_f64() * 1e3),
            format!("{:.6}", sim.pipelined_secs),
            format!("{spj:.6}"),
        ]);
        // The executed counts are exactly the closed forms.
        match scheme {
            BatchScheme::Camr => {
                anyhow::ensure!(out.jobs_executed as u128 == req.camr);
                anyhow::ensure!(out.jobs_required == req.camr);
            }
            BatchScheme::Ccdc => {
                anyhow::ensure!(out.jobs_executed as u128 == req.ccdc, "family fits the cap");
                anyhow::ensure!(out.jobs_required == req.ccdc);
            }
            BatchScheme::Uncoded => anyhow::ensure!(out.jobs_executed as u128 == req.camr),
        }
    }
    print!("{}", t.render());
    println!(
        "\nCAMR ran its whole required set with {} of CCDC's workload floor ({}x fewer jobs).",
        "1/5", // 4 vs 20
        req.ratio()
    );
    let spj = |s: BatchScheme| per_job.iter().find(|(x, _)| *x == s).unwrap().1;
    println!(
        "per-job time: camr {:.6}s, ccdc {:.6}s, uncoded {:.6}s",
        spj(BatchScheme::Camr),
        spj(BatchScheme::Ccdc),
        spj(BatchScheme::Uncoded)
    );

    // Multi-round scaling: the batch pipeline hides round i+1's map
    // phase behind round i's shuffle.
    let rounds = if quick { 2 } else { 8 };
    let opts = BatchOptions { jobs: Some(rounds * cfg.jobs()), ..BatchOptions::default() };
    let out = run_batch_synthetic(&cfg, BatchScheme::Camr, &opts)?;
    let sim = out.simulate(&sc)?;
    anyhow::ensure!(sim.pipelined_secs < sim.serial_secs, "pipelining must save time here");
    println!(
        "\n{} CAMR rounds ({} jobs): barriered {:.6}s, pipelined {:.6}s — saved {:.6}s \
         ({:.1}%)",
        rounds,
        out.jobs_executed,
        sim.serial_secs,
        sim.pipelined_secs,
        sim.saved_secs(),
        100.0 * sim.saved_secs() / sim.serial_secs
    );

    // Table III for reference: the gap explodes with the cluster size.
    println!("\nTable III (K = 100), for scale:");
    let mut t3 = Table::new(vec!["k", "CAMR", "CCDC", "ratio"]);
    for row in camr::analysis::jobs::table3() {
        t3.row(vec![
            row.k.to_string(),
            row.camr.to_string(),
            row.ccdc.to_string(),
            format!("{:.1}x", row.ratio()),
        ]);
    }
    print!("{}", t3.render());
    println!("four_vs_twenty OK");
    Ok(())
}
