//! Quickstart: the paper's running example (Example 1) end to end.
//!
//! Builds the K = 6 / q = 2 / k = 3 system, prints the resolvable-design
//! placement (paper Fig. 1), runs the full map → 3-stage coded shuffle →
//! reduce pipeline on a word-count workload, verifies every output
//! against a single-node oracle, and checks the measured communication
//! load against §IV's closed form (L = 1, split 1/4 + 1/4 + 1/2).
//!
//! Run: `cargo run --release --example quickstart`

use camr::analysis::{jobs, load};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::parallel::ParallelEngine;
use camr::metrics::LoadReport;
use camr::net::Stage;
use camr::report::Table;
use camr::workload::wordcount::WordCountWorkload;

fn main() -> anyhow::Result<()> {
    // Example 1: K = 6 servers, q = 2, k = 3 → J = 4 jobs, N = 6
    // subfiles per job in k = 3 batches of γ = 2.
    let cfg = SystemConfig::new(3, 2, 2)?;
    println!(
        "CAMR quickstart — K={} servers, J={} jobs, N={} subfiles, μ={:.3}\n",
        cfg.servers(),
        cfg.jobs(),
        cfg.subfiles(),
        cfg.storage_fraction()
    );

    let workload = WordCountWorkload::example1(&cfg);
    let mut engine = Engine::new(cfg.clone(), Box::new(workload))?;

    // ---- Fig. 1: the placement.
    println!("Placement (paper Fig. 1) — batches stored per server:");
    let mut t = Table::new(vec!["server", "class", "owns", "stores (job:batch)"]);
    for s in 0..cfg.servers() {
        let m = &engine.master;
        let stored: Vec<String> = m
            .placement
            .inventory(s)
            .iter()
            .map(|(j, b)| format!("J{}:B{}", j + 1, b + 1))
            .collect();
        let owned: Vec<String> =
            m.design.block(s).points.iter().map(|j| format!("J{}", j + 1)).collect();
        t.row(vec![
            format!("U{}", s + 1),
            format!("P{}", m.design.class_of(s) + 1),
            owned.join(","),
            stored.join(" "),
        ]);
    }
    print!("{}", t.render());

    // ---- Run the full pipeline.
    let out = engine.run()?;
    println!("\nShuffle (paper §III-C):");
    for (stage, paper) in
        [(Stage::Stage1, "1/4"), (Stage::Stage2, "1/4"), (Stage::Stage3, "1/2")]
    {
        println!(
            "  {stage}: {:>2} transmissions, {:>5} bytes → load {:.4} (paper: {paper})",
            engine.bus.stage_count(stage),
            engine.bus.stage_bytes(stage),
            engine.bus.stage_load(stage, cfg.load_normalizer()),
        );
    }

    let report = LoadReport::from_outcome(&cfg, &out);
    println!();
    print!("{report}");
    assert!(out.verified, "oracle verification must pass");
    assert!(report.matches_analysis(), "measured load must match §IV");

    // ---- Same run on the thread-per-worker engine: one OS thread per
    // server, coded packets over channels — and the identical ledger.
    let mut par = ParallelEngine::new(cfg.clone(), Box::new(WordCountWorkload::example1(&cfg)))?;
    let pout = par.run()?;
    assert!(pout.verified, "parallel engine must verify too");
    assert_eq!(
        pout.stage_bytes, out.stage_bytes,
        "parallel and serial engines must charge identical bytes"
    );
    println!(
        "\nthread-per-worker engine: same stage bytes {:?}, map {:?} vs serial {:?}",
        pout.stage_bytes, pout.map_time, out.map_time
    );

    // ---- The headline: same load as CCDC, exponentially fewer jobs.
    let req = jobs::JobRequirement::for_params(cfg.k, cfg.q);
    println!(
        "\nSame load as CCDC (L = {:.3} both), but CAMR ran {} jobs \
         where CCDC needs {} (paper §III-C).",
        load::ccdc_total(cfg.k - 1, cfg.servers()),
        req.camr,
        req.ccdc
    );
    println!("quickstart OK");
    Ok(())
}
