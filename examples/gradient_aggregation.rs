//! Distributed SGD gradient aggregation — the paper's §I machine-learning
//! motivation (gradient coding [11]): each job is a model whose gradient
//! is summed across data shards through the CAMR coded shuffle.
//!
//! Runs several SGD steps; every step is one full CAMR round whose
//! reduced outputs are the exact full-batch gradients, which are applied
//! to per-job linear models. Training loss must decrease monotonically —
//! proving the shuffled values are real gradients, not just bytes.
//!
//! Run: `cargo run --release --example gradient_aggregation`

use camr::agg::lanes;
use camr::analysis::load;
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::workload::gradient::GradientWorkload;

fn main() -> anyhow::Result<()> {
    let cfg = SystemConfig::with_options(3, 2, 2, 1, 8)?;
    let params_per_func = cfg.value_bytes / 4; // 2
    let p = cfg.functions() * params_per_func; // 12 parameters per model
    println!(
        "gradient aggregation — K={} servers, J={} models, {} params each\n",
        cfg.servers(),
        cfg.jobs(),
        p
    );

    let steps = 8;
    let lr = 0.08f32;
    // The master copy of the models; each engine run gets a clone.
    let mut master = GradientWorkload::synthetic(&cfg, 7, params_per_func, 4)?;

    for step in 0..steps {
        let losses: Vec<f32> = (0..cfg.jobs()).map(|j| master.loss(j)).collect();
        let truth: Vec<Vec<f32>> =
            (0..cfg.jobs()).map(|j| master.full_gradient(j)).collect();

        // One CAMR round computes every model's full gradient.
        let mut engine = Engine::new(cfg.clone(), Box::new(master.clone()))?;
        let out = engine.run()?;
        anyhow::ensure!(out.verified, "step {step}: oracle verification failed");
        anyhow::ensure!(
            (out.total_load() - load::camr_total(cfg.k, cfg.q)).abs() < 1e-9,
            "step {step}: load deviates from closed form"
        );

        // Collect the reduced gradients and apply the SGD step.
        let mut grads: Vec<Vec<f32>> = vec![vec![0f32; p]; cfg.jobs()];
        for (j, grad) in grads.iter_mut().enumerate() {
            for f in 0..cfg.functions() {
                let slice = lanes::as_f32(engine.output(j, f).expect("output"));
                grad[f * params_per_func..(f + 1) * params_per_func]
                    .copy_from_slice(&slice);
            }
            // The coded-shuffle gradient equals the directly-computed one.
            for (g, t) in grad.iter().zip(&truth[j]) {
                anyhow::ensure!(
                    (g - t).abs() < 2e-3 * 1.0f32.max(t.abs()),
                    "model {j}: shuffled gradient deviates"
                );
            }
        }
        master = master.stepped(&grads, lr);

        let mean: f32 = losses.iter().sum::<f32>() / losses.len() as f32;
        println!(
            "step {step}: mean loss {mean:.5}  (load {:.3}, {} outputs verified)",
            out.total_load(),
            out.outputs
        );
        // Loss must keep dropping.
        let next: Vec<f32> = (0..cfg.jobs()).map(|j| master.loss(j)).collect();
        for (j, (l0, l1)) in losses.iter().zip(&next).enumerate() {
            anyhow::ensure!(l1 < l0, "model {j} loss did not decrease: {l1} !< {l0}");
        }
    }
    println!(
        "\ngradient_aggregation OK — every model's loss decreased across \
         {steps} coded-shuffle SGD steps"
    );
    Ok(())
}
