//! Parameter sweep regenerating the paper's analysis section (§IV–§V):
//! measured CAMR load vs the closed form, CCDC equality at matched μ,
//! uncoded baselines, and the Table-III job-count comparison.
//!
//! Run: `cargo run --release --example load_sweep`

use camr::analysis::{jobs, load};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::report::Table;
use camr::workload::synth::SyntheticWorkload;

fn main() -> anyhow::Result<()> {
    println!("§IV/§V — measured vs analytic loads (every row oracle-verified):\n");
    let mut t = Table::new(vec![
        "k", "q", "K", "J", "mu", "L_meas", "L_form", "L_ccdc", "L_unc_agg", "J_ccdc_min",
    ]);
    for (k, q) in [(2, 2), (2, 4), (3, 2), (3, 3), (3, 4), (4, 2), (4, 3), (5, 2)] {
        // B = 120 is divisible by k-1 for every k here → the packet
        // split is exact and measured load equals the closed form to
        // machine precision.
        let cfg = SystemConfig::with_options(k, q, 2, 1, 120)?;
        let wl = SyntheticWorkload::new(&cfg, 99);
        let mut e = Engine::new(cfg.clone(), Box::new(wl))?;
        let out = e.run()?;
        anyhow::ensure!(out.verified);
        let measured = out.total_load();
        let formula = load::camr_total(k, q);
        anyhow::ensure!(
            (measured - formula).abs() < 1e-9,
            "k={k} q={q}: measured {measured} != formula {formula}"
        );
        t.row(vec![
            k.to_string(),
            q.to_string(),
            cfg.servers().to_string(),
            cfg.jobs().to_string(),
            format!("{:.4}", cfg.storage_fraction()),
            format!("{measured:.4}"),
            format!("{formula:.4}"),
            format!("{:.4}", load::ccdc_total(k - 1, cfg.servers())),
            format!("{:.4}", load::uncoded_aggregated_total(k, q)),
            jobs::JobRequirement::for_params(k, q).ccdc.to_string(),
        ]);
    }
    print!("{}", t.render());

    println!("\nTable III — minimum number of jobs at K = 100:\n");
    let mut t3 = Table::new(vec!["k", "J_CAMR", "J_CCDC", "ratio"]);
    for row in jobs::table3() {
        t3.row(vec![
            row.k.to_string(),
            row.camr.to_string(),
            row.ccdc.to_string(),
            format!("{:.0}x", row.ratio()),
        ]);
    }
    print!("{}", t3.render());
    println!(
        "\nload_sweep OK (L_CAMR == L_CCDC at equal μ in every row; \
         CCDC needs exponentially more jobs)"
    );
    Ok(())
}
