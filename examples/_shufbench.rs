//! Internal perf harness (§Perf): shuffle wall time + encode/decode
//! micro-comparison between the cloning and zero-copy APIs.
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::coordinator::master::Master;
use camr::coordinator::values::ValueKey;
use camr::coordinator::worker::Worker;
use camr::shuffle::multicast::GroupPlan;
use camr::workload::synth::SyntheticWorkload;
use std::time::Instant;

fn main() {
    for (k, q, g, b) in [(3usize, 4usize, 4usize, 4096usize), (4, 3, 2, 4096), (3, 2, 2, 65536)] {
        let cfg = SystemConfig::with_options(k, q, g, 1, b).unwrap();
        let mut best = u128::MAX;
        let mut sum = 0u128;
        let n = 15;
        for _ in 0..n {
            let wl = SyntheticWorkload::new(&cfg, 9);
            let mut e = Engine::new(cfg.clone(), Box::new(wl)).unwrap();
            e.verify = false;
            let out = e.run().unwrap();
            let ns = out.shuffle_time.as_nanos();
            best = best.min(ns);
            sum += ns;
        }
        println!(
            "SHUF k={k} q={q} B={b}: mean {}µs min {}µs",
            sum / n as u128 / 1000,
            best / 1000
        );
    }

    // Micro: encode+decode one stage-2 schedule, cloning vs zero-copy.
    let cfg = SystemConfig::with_options(4, 3, 2, 1, 4096).unwrap();
    let master = Master::new(cfg.clone()).unwrap();
    let schedule = master.schedule().unwrap();
    let wl = SyntheticWorkload::new(&cfg, 9);
    let mut workers: Vec<Worker> = (0..cfg.servers()).map(|s| Worker::new(s, &cfg)).collect();
    for w in workers.iter_mut() {
        w.run_map_phase(&cfg, &master.placement, &wl).unwrap();
    }
    let groups: Vec<&GroupPlan> = schedule.stage1.iter().chain(schedule.stage2.iter()).collect();

    let chunk = |w: &Worker, plan: &GroupPlan, p: usize| -> camr::error::Result<Vec<u8>> {
        let c = plan.chunks[p];
        Ok(w.store.get(ValueKey { job: c.job, func: c.func, batch: c.batch })?.clone())
    };

    for mode in ["cloning", "zerocopy"] {
        let mut best = u128::MAX;
        for _ in 0..20 {
            let t = Instant::now();
            let mut total = 0usize;
            for plan in &groups {
                let deltas: Vec<Vec<u8>> = plan
                    .members
                    .iter()
                    .enumerate()
                    .map(|(t_pos, &m)| {
                        if mode == "cloning" {
                            plan.encode(t_pos, cfg.value_bytes, |p| chunk(&workers[m], plan, p))
                                .unwrap()
                        } else {
                            workers[m].encode_for_group(plan).unwrap()
                        }
                    })
                    .collect();
                for (r, &m) in plan.members.iter().enumerate() {
                    let out = if mode == "cloning" {
                        plan.decode(r, cfg.value_bytes, &deltas, |p| chunk(&workers[m], plan, p))
                            .unwrap()
                    } else {
                        plan.decode_ref(r, cfg.value_bytes, &deltas, |p| {
                            let c = plan.chunks[p];
                            Ok(workers[m]
                                .store
                                .get(ValueKey { job: c.job, func: c.func, batch: c.batch })?
                                .as_slice())
                        })
                        .unwrap()
                    };
                    total += out.len();
                }
            }
            std::hint::black_box(total);
            best = best.min(t.elapsed().as_nanos());
        }
        println!("MICRO encode+decode[{mode}]: min {}µs", best / 1000);
    }
}
