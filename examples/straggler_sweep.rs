//! Bandwidth crossover: where does CAMR's extra map work pay for
//! itself?
//!
//! CAMR maps every subfile `k-1` times to shrink the shuffle. Against a
//! *minimal-map* baseline (every batch stored and mapped exactly once,
//! round-robin; reducers fetch every non-local batch aggregate as a
//! unicast) that is a real trade: `(k-1)×` the map compute for roughly
//! `1/(2-k/K)…` of the bytes. On a fast network the minimal mapper wins
//! (compute-bound); on a slow one CAMR wins (shuffle-bound). This
//! example sweeps link bandwidth under shifted-exponential stragglers,
//! brackets the crossover by bisection on the simulator, and
//! cross-checks it against the closed form
//! `bw* = Δbytes / Δmap_secs` (exact because latency = 0 makes the
//! simulated shuffle time `bytes/bw`).
//!
//! Run: `cargo run --release --example straggler_sweep [-- --quick]`

use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::net::{Bus, Stage, Transmission};
use camr::report::Table;
use camr::sim::{self, LinkKind, SimConfig, StragglerModel};
use camr::workload::synth::SyntheticWorkload;

/// Minimal-map scenario: single-copy round-robin placement (batch
/// `(j, b)` lives only on server `(j·k + b) mod K`), so the map phase
/// does `1/(k-1)` of CAMR's work, and every reducer unicast-fetches
/// each non-local batch aggregate.
fn minimal_map_scenario(cfg: &SystemConfig) -> (Vec<usize>, Bus) {
    let servers = cfg.servers();
    let mut maps = vec![0usize; servers];
    let mut bus = Bus::new();
    for j in 0..cfg.jobs() {
        for b in 0..cfg.batches() {
            maps[(j * cfg.batches() + b) % servers] += cfg.gamma;
        }
    }
    for f in 0..cfg.functions() {
        let m = cfg.reducer_of(f);
        for j in 0..cfg.jobs() {
            for b in 0..cfg.batches() {
                let holder = (j * cfg.batches() + b) % servers;
                if holder != m {
                    bus.unicast(Stage::Baseline, holder, m, cfg.value_bytes);
                }
            }
        }
    }
    (maps, bus)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = SystemConfig::new(3, 2, 2)?;

    // CAMR's byte-exact ledger from a real run.
    let wl = SyntheticWorkload::new(&cfg, 7);
    let mut e = Engine::new(cfg.clone(), Box::new(wl))?;
    e.verify = false;
    e.run()?;
    let camr_maps = sim::camr_per_worker_maps(&cfg, &e.master.placement);
    let camr_ledger: Vec<Transmission> = e.bus.ledger().to_vec();
    let (min_maps, min_bus) = minimal_map_scenario(&cfg);

    let base = SimConfig {
        link: LinkKind::Shared,
        link_bytes_per_sec: 1.0, // overwritten per sweep point
        latency_secs: 0.0,
        secs_per_map: 1e-3,
        speeds: Vec::new(),
        straggler: StragglerModel::ShiftedExp { rate: 5.0 },
        seed: 42,
    };
    let at = |bw: f64| -> anyhow::Result<(f64, f64)> {
        let mut sc = base.clone();
        sc.link_bytes_per_sec = bw;
        let c = sim::simulate(&sc, &camr_maps, &camr_ledger)?;
        let m = sim::simulate(&sc, &min_maps, min_bus.ledger())?;
        Ok((c.total_secs, m.total_secs))
    };

    let camr_tasks: usize = camr_maps.iter().sum();
    let min_tasks: usize = min_maps.iter().sum();
    let camr_bytes: usize = camr_ledger.iter().map(|t| t.bytes).sum();
    let min_bytes: usize = min_bus.ledger().iter().map(|t| t.bytes).sum();
    println!(
        "CAMR vs minimal-map baseline — K={} J={} γ={} B={} (shifted_exp stragglers, seed 42)",
        cfg.servers(),
        cfg.jobs(),
        cfg.gamma,
        cfg.value_bytes
    );
    println!(
        "  map tasks: camr {camr_tasks} vs minimal {min_tasks} ({}x extra compute)",
        camr_tasks / min_tasks
    );
    println!("  shuffle bytes: camr {camr_bytes} vs minimal {min_bytes}\n");
    anyhow::ensure!(camr_tasks > min_tasks, "CAMR must do extra map work");
    anyhow::ensure!(camr_bytes < min_bytes, "CAMR must move fewer bytes");

    // Log-spaced bandwidth sweep.
    let points = if quick { 6 } else { 11 };
    let (lo_exp, hi_exp) = (4.0f64, 9.0f64);
    let mut t = Table::new(vec!["bw_bytes_per_sec", "t_camr", "t_minimal", "winner"]);
    for i in 0..points {
        let bw = 10f64.powf(lo_exp + (hi_exp - lo_exp) * i as f64 / (points - 1) as f64);
        let (tc, tm) = at(bw)?;
        t.row(vec![
            format!("{bw:.3e}"),
            format!("{tc:.6}"),
            format!("{tm:.6}"),
            if tc < tm { "camr" } else { "minimal" }.to_string(),
        ]);
    }
    print!("{}", t.render());

    // The regimes must flip across the sweep.
    let (tc_slow, tm_slow) = at(10f64.powf(lo_exp))?;
    let (tc_fast, tm_fast) = at(10f64.powf(hi_exp))?;
    anyhow::ensure!(tc_slow < tm_slow, "on a slow link CAMR must win");
    anyhow::ensure!(tc_fast > tm_fast, "on a fast link minimal-map must win");

    // Bisect the crossover on log10(bandwidth).
    let (mut lo, mut hi) = (lo_exp, hi_exp);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let (tc, tm) = at(10f64.powf(mid))?;
        if tc < tm {
            lo = mid; // CAMR still winning: crossover is at higher bw.
        } else {
            hi = mid;
        }
    }
    let crossover = 10f64.powf(0.5 * (lo + hi));

    // Closed-form cross-check: with zero latency the simulated time is
    // map_secs + bytes/bw, so t_camr = t_min at Δbytes / Δmap_secs.
    let (c_fast, m_fast) = {
        let mut sc = base.clone();
        sc.link_bytes_per_sec = 1e30; // shuffle ≈ 0: read off map_secs
        (
            sim::simulate(&sc, &camr_maps, &camr_ledger)?.map_secs,
            sim::simulate(&sc, &min_maps, min_bus.ledger())?.map_secs,
        )
    };
    let analytic = (min_bytes - camr_bytes) as f64 / (c_fast - m_fast);
    anyhow::ensure!(
        (crossover - analytic).abs() / analytic < 1e-6,
        "bisected {crossover} vs analytic {analytic}"
    );
    println!(
        "\ncrossover: {crossover:.4e} B/s ({:.2} Mbit/s) — below this, CAMR's extra map \
         work pays for itself (analytic {analytic:.4e} B/s)",
        crossover * 8.0 / 1e6
    );
    println!("straggler_sweep OK");
    Ok(())
}
