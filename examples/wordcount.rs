//! Word counting at scale: CAMR vs the uncoded baselines on a larger
//! synthetic corpus (the paper's Example-1 workload class, §II).
//!
//! Runs the same job set through three shuffles — CAMR coded, uncoded
//! aggregated, uncoded raw — verifying every reduce output each time,
//! and prints the measured load comparison. This regenerates the
//! compression-vs-coding decomposition the paper's intro motivates:
//! aggregation buys ~γk×, coding buys the rest.
//!
//! Run: `cargo run --release --example wordcount`

use camr::analysis::load;
use camr::baseline::{UncodedEngine, UncodedMode};
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::report::Table;
use camr::workload::wordcount::WordCountWorkload;

fn main() -> anyhow::Result<()> {
    // A 12-server cluster counting words in 9 books of 12 chapters.
    let cfg = SystemConfig::new(3, 4, 4)?;
    println!(
        "wordcount — K={} servers, J={} books, N={} chapters each, Q={} words/book\n",
        cfg.servers(),
        cfg.jobs(),
        cfg.subfiles(),
        cfg.functions()
    );

    let mut rows: Vec<(String, f64, f64, bool)> = Vec::new();

    // CAMR coded shuffle.
    {
        let wl = WordCountWorkload::synthetic(&cfg, 2024, 120);
        let mut e = Engine::new(cfg.clone(), Box::new(wl))?;
        let out = e.run()?;
        rows.push((
            "CAMR (coded)".into(),
            out.total_load(),
            load::camr_total(cfg.k, cfg.q),
            out.verified,
        ));
    }
    // Uncoded but aggregated.
    {
        let wl = WordCountWorkload::synthetic(&cfg, 2024, 120);
        let mut e = UncodedEngine::new(cfg.clone(), Box::new(wl), UncodedMode::Aggregated)?;
        let out = e.run()?;
        rows.push((
            "uncoded aggregated".into(),
            out.load(),
            load::uncoded_aggregated_total(cfg.k, cfg.q),
            out.verified,
        ));
    }
    // Uncoded, unaggregated (vanilla MapReduce shuffle).
    {
        let wl = WordCountWorkload::synthetic(&cfg, 2024, 120);
        let mut e = UncodedEngine::new(cfg.clone(), Box::new(wl), UncodedMode::Raw)?;
        let out = e.run()?;
        rows.push((
            "uncoded raw".into(),
            out.load(),
            load::uncoded_raw_total(cfg.k, cfg.q, cfg.gamma),
            out.verified,
        ));
    }

    let mut t = Table::new(vec!["scheme", "L (measured)", "L (closed form)", "verified"]);
    for (name, measured, formula, verified) in &rows {
        t.row(vec![
            name.clone(),
            format!("{measured:.4}"),
            format!("{formula:.4}"),
            verified.to_string(),
        ]);
    }
    print!("{}", t.render());

    let camr = rows[0].1;
    let agg = rows[1].1;
    let raw = rows[2].1;
    println!(
        "\naggregation gain: {:.1}x   coding gain on top: {:.2}x   total: {:.1}x",
        raw / agg,
        agg / camr,
        raw / camr
    );
    assert!(rows.iter().all(|r| r.3), "all schemes must verify");
    println!("wordcount OK");
    Ok(())
}
