//! END-TO-END driver (DESIGN.md E10): neural-network layer matvec jobs
//! through **all three layers** of the stack.
//!
//! - Layer 1/2: the map compute is the AOT-compiled JAX + Pallas matvec
//!   kernel (`artifacts/map_kernel.hlo.txt`, built once by
//!   `make artifacts`), executed from rust through PJRT. Python never
//!   runs here.
//! - Layer 3: the CAMR coordinator places shards per Algorithm 1, runs
//!   the 3-stage coded shuffle byte-exactly, and reduces.
//!
//! Every output row-slice is verified against (a) the single-node oracle
//! through the same PJRT kernel and (b) a pure-rust full product. The
//! run reports the paper's headline metric — communication load vs the
//! §IV closed form — plus wall-clock phase breakdown and map throughput.
//!
//! Run: `cargo run --release --example matvec_pipeline -- artifacts/map_kernel.hlo.txt`
//! (falls back to the native rust mapper if the artifact is missing).

use camr::agg::lanes;
use camr::analysis::load;
use camr::config::SystemConfig;
use camr::coordinator::engine::Engine;
use camr::metrics::LoadReport;
use camr::runtime::PjrtShardCompute;
use camr::workload::matvec::{MatVecWorkload, NativeShardCompute, ShardCompute};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifact = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts/map_kernel.hlo.txt"));

    // K = 6 cluster; M = 96-row layer (Q = 6 output slices of 16 rows =
    // 64-byte values), D = 48 input dims in 6 column shards of 8.
    let cfg = SystemConfig::new(3, 2, 2)?;
    let rows_per_func = cfg.value_bytes / 4; // 16
    let cols_per_subfile = 8usize;

    let compute: Arc<dyn ShardCompute> = if artifact.exists() {
        println!("loading AOT artifact {} (JAX+Pallas via PJRT)", artifact.display());
        let c = PjrtShardCompute::new(&artifact)?;
        let (m, cols) = c.shape();
        anyhow::ensure!(
            m == cfg.functions() * rows_per_func && cols == cols_per_subfile,
            "artifact shape {m}x{cols} does not match workload; re-run `make artifacts`"
        );
        Arc::new(c)
    } else {
        println!("artifact {} not found — using native mapper", artifact.display());
        Arc::new(NativeShardCompute)
    };
    let backend = compute.name();

    let wl = MatVecWorkload::synthetic(&cfg, 0xA11CE, rows_per_func, cols_per_subfile, compute)?;
    // Independent pure-rust ground truth, computed before the engine
    // consumes the workload.
    let truth: Vec<Vec<f32>> = (0..cfg.jobs()).map(|j| wl.full_product(j)).collect();

    println!(
        "matvec pipeline — K={} J={} jobs, layer {}x{}, mapper = {backend}\n",
        cfg.servers(),
        cfg.jobs(),
        cfg.functions() * rows_per_func,
        cfg.subfiles() * cols_per_subfile,
    );

    let t0 = Instant::now();
    let mut engine = Engine::new(cfg.clone(), Box::new(wl))?;
    let out = engine.run()?;
    let wall = t0.elapsed();

    // Cross-check every reduced output against the pure-rust truth.
    let mut checked = 0usize;
    for j in 0..cfg.jobs() {
        for f in 0..cfg.functions() {
            let got = lanes::as_f32(engine.output(j, f).expect("output"));
            let want = &truth[j][f * rows_per_func..(f + 1) * rows_per_func];
            for (g, w) in got.iter().zip(want) {
                anyhow::ensure!(
                    (g - w).abs() <= 2e-4 * 1.0f32.max(w.abs()),
                    "job {j} func {f}: {g} vs {w}"
                );
                checked += 1;
            }
        }
    }

    let report = LoadReport::from_outcome(&cfg, &out);
    print!("{report}");
    println!(
        "\nverified {checked} output lanes against pure-rust ground truth (PJRT path: {})",
        backend == "pjrt"
    );
    println!(
        "wall {:.1} ms  ({} map invocations, {:.0} maps/s through {backend})",
        wall.as_secs_f64() * 1e3,
        out.map_invocations,
        out.map_invocations as f64 / out.map_time.as_secs_f64().max(1e-9)
    );
    anyhow::ensure!(out.verified, "oracle verification failed");
    anyhow::ensure!(
        (out.total_load() - load::camr_total(cfg.k, cfg.q)).abs() < 1e-9,
        "measured load must match §IV closed form"
    );
    println!("matvec_pipeline OK");
    Ok(())
}
